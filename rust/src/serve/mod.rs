//! `frctl serve` — a std-only inference + experiment service over the
//! resident-parameter pipeline.
//!
//! The same machinery the trainer built to keep K module workers busy
//! (resident params, bounded waits, crash-safe checkpoints) is what a
//! serving layer needs. This subsystem adds the missing front half:
//!
//! - [`http`]: hand-rolled HTTP/1.1 with strict limits and typed errors
//! - [`router`]: `(method, path)` dispatch to the `/v1/*` endpoints
//! - [`batcher`]: coalesces concurrent predict requests into dynamic
//!   micro-batches (flush on `max_batch` or `max_wait_ms`) that run one
//!   fixed-batch forward pass through the module chain
//! - [`jobs`]: background training jobs on the threaded `ParallelFr`
//!   fleet, streaming per-step metrics as JSON lines and writing
//!   checkpoints through the crash-safety substrate
//! - [`json`]: typed request decoding (malformed bodies → 400, never a
//!   panic)
//!
//! The [`Server`] itself is two phases: [`Server::bind`] resolves the
//! model, warms the batcher session and binds the listener (failures here
//! are configuration errors → exit 2), then [`Server::run`] accepts
//! connections thread-per-connection with keep-alive until SIGTERM/SIGINT
//! (or a programmatic stop handle) and tears down gracefully.

pub mod batcher;
pub mod http;
pub mod jobs;
pub mod json;
pub mod router;

use std::net::TcpListener;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::experiment::Experiment;
use crate::metrics::hist::{Counter, Histogram};
use crate::runtime::Packer;
use crate::util::json::{num, obj, Json};

/// Everything `frctl serve` (and the bench/tests) configures.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Bind address; port 0 picks an ephemeral port (printed on startup).
    pub addr: String,
    /// Registry model served by `/v1/predict`.
    pub model: String,
    pub k: usize,
    pub threads: usize,
    pub seed: u64,
    /// Micro-batch flush size; 0 = the model's compiled batch capacity.
    /// Clamped to the capacity either way.
    pub max_batch: usize,
    /// How long the batcher holds an open micro-batch for more requests.
    pub max_wait_ms: u64,
    /// Where train jobs stream `job-<id>.jsonl` metrics + checkpoints.
    pub jobs_dir: PathBuf,
    /// Optional checkpoint (file or dir) to warm-start the served weights.
    pub resume: Option<PathBuf>,
}

impl ServeConfig {
    /// Defaults matching the `frctl serve` flag defaults.
    pub fn new(model: &str) -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:8484".to_string(),
            model: model.to_string(),
            k: 4,
            threads: 0,
            seed: 0,
            max_batch: 0,
            max_wait_ms: 5,
            jobs_dir: std::env::temp_dir()
                .join(format!("frctl-serve-jobs-{}", std::process::id())),
            resume: None,
        }
    }
}

/// Process-wide serving metrics: latency histograms + counters, shared
/// between the request path, the batcher and the background train jobs
/// (`train_step_ms` is the same series the training loop feeds). Snapshot
/// via [`ServeMetrics::to_json`] — the `/v1/metrics` body.
#[derive(Default)]
pub struct ServeMetrics {
    /// Full request handling time (parse → response written).
    pub request_ms: Histogram,
    /// Predict time spent queued before a micro-batch flushed.
    pub queue_ms: Histogram,
    /// Micro-batch forward-pass time.
    pub compute_ms: Histogram,
    /// Background-job training step time (shared with training).
    pub train_step_ms: Histogram,
    pub requests_total: Counter,
    pub predict_requests: Counter,
    pub predict_errors: Counter,
    /// Requests refused at the HTTP layer (malformed → 400).
    pub http_errors: Counter,
    pub predict_batches: Counter,
    pub predict_samples: Counter,
    pub jobs_started: Counter,
    pub jobs_completed: Counter,
    pub jobs_failed: Counter,
}

impl ServeMetrics {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("requests_total", num(self.requests_total.get() as f64)),
            ("predict_requests", num(self.predict_requests.get() as f64)),
            ("predict_errors", num(self.predict_errors.get() as f64)),
            ("http_errors", num(self.http_errors.get() as f64)),
            ("predict_batches", num(self.predict_batches.get() as f64)),
            ("predict_samples", num(self.predict_samples.get() as f64)),
            ("jobs_started", num(self.jobs_started.get() as f64)),
            ("jobs_completed", num(self.jobs_completed.get() as f64)),
            ("jobs_failed", num(self.jobs_failed.get() as f64)),
            ("request_latency", self.request_ms.to_json()),
            ("queue_latency", self.queue_ms.to_json()),
            ("compute_latency", self.compute_ms.to_json()),
            ("train_step_latency", self.train_step_ms.to_json()),
        ])
    }
}

/// Lock a serve-side mutex, recovering from poisoning. A handler thread
/// that panicked mid-update must not cascade into a panic on every later
/// request touching the same lock; serve's shared structures (pending
/// queue, job list, join-handle slots) are append/drain shapes whose
/// partially-updated states are still safe to observe. This is the only
/// sanctioned way to lock under `src/serve/` — `.lock().expect(…)` trips
/// the frlint `serve-unwrap` rule.
pub(crate) fn lock<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(guard) => guard,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// SIGTERM/SIGINT flip this; the accept loop polls it. Separate from the
/// per-server stop handle so in-process servers (tests, bench) stop
/// without signals.
static SIGNALLED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
fn install_signal_handlers() {
    extern "C" fn on_signal(_sig: i32) {
        // async-signal-safe: one relaxed atomic store
        SIGNALLED.store(true, Ordering::Relaxed);
    }
    extern "C" {
        // libc is already linked by std on unix; SIG_ERR return ignored
        // (worst case: no graceful shutdown, same as before this existed)
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    unsafe {
        signal(SIGTERM, on_signal as extern "C" fn(i32) as usize);
        signal(SIGINT, on_signal as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_signal_handlers() {}

/// Concurrent-connection cap: beyond this, new connections get an
/// immediate 503 instead of a handler thread.
const MAX_CONNECTIONS: usize = 128;

/// A bound, ready-to-run server. See the module docs for the two-phase
/// (bind = config errors, run = runtime errors) split.
pub struct Server {
    listener: TcpListener,
    app: Arc<router::App>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Configuration phase: resolve the model through the registry, build
    /// the batcher's session (warm-starting from `resume` if given), bind
    /// the listener. Every failure here means nothing is serving yet.
    pub fn bind(cfg: ServeConfig) -> Result<Server> {
        let exp = Experiment::new(&cfg.model)
            .k(cfg.k)
            .threads(cfg.threads)
            .seed(cfg.seed);
        let manifest = exp.manifest()
            .with_context(|| format!("resolving model {:?}", cfg.model))?;
        let packer = Packer::new(&manifest)?;
        let capacity = packer.capacity();
        let max_batch = match cfg.max_batch {
            0 => capacity,
            n => n.min(capacity),
        };
        let metrics = Arc::new(ServeMetrics::default());
        let batcher = batcher::Batcher::spawn(
            exp, cfg.resume.clone(), max_batch,
            Duration::from_millis(cfg.max_wait_ms), Arc::clone(&metrics))?;
        let jobs = jobs::JobRegistry::new(cfg.jobs_dir.clone(), Arc::clone(&metrics))?;
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let app = Arc::new(router::App {
            model: cfg.model.clone(),
            manifest,
            packer,
            batcher,
            jobs,
            metrics,
            started: Instant::now(),
            max_batch,
            max_wait_ms: cfg.max_wait_ms,
        });
        Ok(Server { listener, app, stop: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        // a bound TcpListener always has a local address; a failure here is
        // unreachable OS state, never client input
        // frlint: allow(serve-unwrap) — bound listener, unreachable OS state
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Flip to stop an in-process server (tests/bench) — the accept loop
    /// notices within one poll interval and tears down like SIGTERM.
    pub fn stop_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Accept loop until SIGTERM/SIGINT or the stop handle flips, then
    /// graceful teardown: drain connection handlers, stop the batcher,
    /// stop-and-join the job fleet.
    pub fn run(self) -> Result<()> {
        install_signal_handlers();
        let addr = self.local_addr();
        // the CI smoke and tests parse this line for the ephemeral port
        println!("frctl serve: listening on http://{addr} (model {}, \
                  max_batch {}, max_wait {} ms)",
                 self.app.model, self.app.max_batch, self.app.max_wait_ms);
        use std::io::Write as _;
        std::io::stdout().flush().ok();

        self.listener.set_nonblocking(true)
            .context("listener set_nonblocking")?;
        let live = Arc::new(AtomicUsize::new(0));
        let mut handlers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) && !SIGNALLED.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    if live.load(Ordering::Relaxed) >= MAX_CONNECTIONS {
                        let mut stream = stream;
                        let resp = router::ApiError::Unavailable(
                            "connection limit reached".to_string()).to_response();
                        let _ = resp.write_to(&mut stream);
                        continue;
                    }
                    live.fetch_add(1, Ordering::Relaxed);
                    let app = Arc::clone(&self.app);
                    let stop = Arc::clone(&self.stop);
                    let live = Arc::clone(&live);
                    handlers.push(std::thread::spawn(move || {
                        handle_connection(app, stop, stream);
                        live.fetch_sub(1, Ordering::Relaxed);
                    }));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(_) => std::thread::sleep(Duration::from_millis(5)),
            }
            // reap finished handlers so a long-lived server doesn't
            // accumulate JoinHandles
            if handlers.len() > MAX_CONNECTIONS {
                handlers.retain(|h| !h.is_finished());
            }
        }

        drop(self.listener);
        // wake idle keep-alive handlers (they poll `stop` on read timeout)
        self.stop.store(true, Ordering::Relaxed);
        for h in handlers {
            let _ = h.join();
        }
        self.app.batcher.shutdown();
        self.app.jobs.shutdown();
        println!("frctl serve: clean shutdown ({} requests served)",
                 self.app.metrics.requests_total.get());
        Ok(())
    }
}

/// Per-connection loop: keep-alive request/response until the peer closes,
/// a fatal parse/transport error, or server shutdown. An idle connection
/// wakes every 500 ms to poll the stop flag.
fn handle_connection(app: Arc<router::App>, stop: Arc<AtomicBool>,
                     stream: std::net::TcpStream) {
    use std::io::BufRead as _;

    stream.set_nodelay(true).ok();
    if stream.set_read_timeout(Some(Duration::from_millis(500))).is_err() {
        return;
    }
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = std::io::BufReader::new(read_half);
    let mut writer = stream;
    loop {
        // idle wait: poll readability so a timeout can never split a
        // request that started arriving (fill_buf consumes nothing)
        match reader.fill_buf() {
            Ok([]) => break, // clean EOF
            Ok(_) => {}
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut => {
                if stop.load(Ordering::Relaxed) || SIGNALLED.load(Ordering::Relaxed) {
                    break;
                }
                continue;
            }
            Err(_) => break,
        }
        match http::read_request(&mut reader) {
            Ok(None) => break,
            Ok(Some(req)) => {
                let t0 = Instant::now();
                app.metrics.requests_total.inc();
                let mut resp = router::handle(&app, &req);
                resp.close |= req.wants_close();
                let write_ok = resp.write_to(&mut writer).is_ok();
                app.metrics.request_ms.record(t0.elapsed());
                if !write_ok || resp.close {
                    break;
                }
            }
            Err(e) => {
                if e.is_client_fault() {
                    app.metrics.http_errors.inc();
                    let mut resp = router::ApiError::BadRequest(e.to_string())
                        .to_response();
                    resp.close = true;
                    let _ = resp.write_to(&mut writer);
                }
                break;
            }
        }
    }
}
