//! Background training jobs for `frctl serve`.
//!
//! `POST /v1/train-jobs` lands here: each job gets its own thread driven
//! through the usual [`Experiment`] builder — a
//! [`crate::coordinator::parallel::ParallelFr`] fleet for FR, a sequential
//! [`crate::experiment::Session`] for every other algorithm — stepped to
//! completion while streaming per-step metrics as incrementally flushed
//! JSON lines (`job-<id>.jsonl` under the jobs dir) so a client can tail
//! progress mid-run. Both paths share the same NDJSON schema, stop flag,
//! checkpoint cadence and final eval. Jobs share the serve metrics
//! (per-step latency histogram, started/completed/failed counters) and
//! honour the PR 6 checkpoint substrate when the spec asks for a cadence.

use std::io::Write;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::coordinator::Algo;
use crate::experiment::Experiment;
use crate::serve::{lock, ServeMetrics};
use crate::util::json::{num, obj, s, Json};

/// Validated request for one background training run (bounds enforced by
/// [`crate::serve::json::decode_train_job`]).
#[derive(Clone, Debug)]
pub struct TrainJobSpec {
    pub model: String,
    pub algo: Algo,
    pub k: usize,
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    pub threads: usize,
    pub checkpoint_every: usize,
}

impl TrainJobSpec {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("model", s(&self.model)),
            ("algo", s(self.algo.cli_name())),
            ("k", num(self.k as f64)),
            ("steps", num(self.steps as f64)),
            ("lr", num(self.lr as f64)),
            ("seed", num(self.seed as f64)),
            ("threads", num(self.threads as f64)),
            ("checkpoint_every", num(self.checkpoint_every as f64)),
        ])
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum JobState {
    Running,
    Done,
    Failed,
    Stopped,
}

impl JobState {
    fn as_str(self) -> &'static str {
        match self {
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Stopped => "stopped",
        }
    }
}

#[derive(Default)]
struct Progress {
    step: usize,
    last_loss: f64,
    error: Option<String>,
    eval: Option<(f64, f64)>,
}

struct Job {
    id: usize,
    spec: TrainJobSpec,
    stop: AtomicBool,
    state: Mutex<JobState>,
    progress: Mutex<Progress>,
    handle: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Job {
    fn set_state(&self, next: JobState) {
        *lock(&self.state) = next;
    }

    fn to_json(&self) -> Json {
        let state = *lock(&self.state);
        let p = lock(&self.progress);
        let mut fields = vec![
            ("id", num(self.id as f64)),
            ("state", s(state.as_str())),
            ("step", num(p.step as f64)),
            ("last_loss", num(p.last_loss)),
            ("spec", self.spec.to_json()),
        ];
        if let Some(err) = &p.error {
            fields.push(("error", s(err)));
        }
        if let Some((loss, errr)) = p.eval {
            fields.push(("eval_loss", num(loss)));
            fields.push(("eval_err", num(errr)));
        }
        obj(fields)
    }
}

/// Owns every background job; the router talks only to this.
pub struct JobRegistry {
    dir: PathBuf,
    metrics: Arc<ServeMetrics>,
    jobs: Mutex<Vec<Arc<Job>>>,
    next_id: AtomicUsize,
}

impl JobRegistry {
    pub fn new(dir: PathBuf, metrics: Arc<ServeMetrics>) -> Result<JobRegistry> {
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating jobs dir {}", dir.display()))?;
        Ok(JobRegistry {
            dir,
            metrics,
            jobs: Mutex::new(Vec::new()),
            next_id: AtomicUsize::new(1),
        })
    }

    fn metrics_path(&self, id: usize) -> PathBuf {
        self.dir.join(format!("job-{id}.jsonl"))
    }

    /// Start a job thread and return its id immediately; model resolution
    /// happens on the thread, so a bad model shows up as a failed job, not
    /// a blocked submit. Failing to spawn the thread at all (resource
    /// exhaustion) is the one submit-time error — typed, so the router can
    /// answer 503 instead of the old panic.
    pub fn submit(&self, spec: TrainJobSpec) -> Result<usize> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Arc::new(Job {
            id,
            spec: spec.clone(),
            stop: AtomicBool::new(false),
            state: Mutex::new(JobState::Running),
            progress: Mutex::new(Progress::default()),
            handle: Mutex::new(None),
        });
        let worker_job = Arc::clone(&job);
        let worker_metrics = Arc::clone(&self.metrics);
        let jsonl = self.metrics_path(id);
        let ckpt_dir = self.dir.join(format!("job-{id}-ckpt"));
        let handle = std::thread::Builder::new()
            .name(format!("fr-job-{id}"))
            .spawn(move || {
                let outcome = run_job(&worker_job, &jsonl, &ckpt_dir, &worker_metrics);
                match outcome {
                    // frlint: allow(serve-unwrap) — logic-bug guard, no client input
                    Ok(JobState::Running) => unreachable!("run_job returns a final state"),
                    Ok(done) => {
                        if done == JobState::Done {
                            worker_metrics.jobs_completed.inc();
                        }
                        worker_job.set_state(done);
                    }
                    Err(e) => {
                        worker_metrics.jobs_failed.inc();
                        lock(&worker_job.progress).error = Some(format!("{e:#}"));
                        worker_job.set_state(JobState::Failed);
                    }
                }
            })
            .context("spawning job thread")?;
        self.metrics.jobs_started.inc();
        *lock(&job.handle) = Some(handle);
        lock(&self.jobs).push(job);
        Ok(id)
    }

    pub fn list(&self) -> Json {
        let jobs = lock(&self.jobs);
        obj(vec![("jobs", Json::Arr(jobs.iter().map(|j| j.to_json()).collect()))])
    }

    pub fn get(&self, id: usize) -> Option<Json> {
        lock(&self.jobs).iter()
            .find(|j| j.id == id)
            .map(|j| j.to_json())
    }

    /// Raw NDJSON step stream for a job (what the thread has flushed so
    /// far). None if the id is unknown.
    pub fn read_metrics(&self, id: usize) -> Option<Vec<u8>> {
        let known = lock(&self.jobs).iter().any(|j| j.id == id);
        if !known {
            return None;
        }
        // the file appears with the first flushed step; empty until then
        Some(std::fs::read(self.metrics_path(id)).unwrap_or_default())
    }

    /// Ask every job to stop after its current step, then join them.
    pub fn shutdown(&self) {
        let jobs: Vec<Arc<Job>> = lock(&self.jobs).clone();
        for job in &jobs {
            job.stop.store(true, Ordering::Relaxed);
        }
        for job in &jobs {
            if let Some(h) = lock(&job.handle).take() {
                let _ = h.join();
            }
        }
    }
}

/// The job thread body: build the experiment, then dispatch on algorithm —
/// FR runs on the threaded K-worker fleet, every other strategy steps a
/// sequential session. Both paths stream one JSON line per step,
/// checkpoint on cadence, and eval at the end. Returns the final state
/// (`Done` or `Stopped`); any error tears the run down and fails the job.
fn run_job(job: &Job, jsonl: &std::path::Path, ckpt_dir: &std::path::Path,
           metrics: &ServeMetrics) -> Result<JobState> {
    let spec = &job.spec;
    let mut exp = Experiment::new(&spec.model)
        .algo(spec.algo)
        .k(spec.k)
        .steps(spec.steps)
        .lr(spec.lr)
        .seed(spec.seed)
        .threads(spec.threads);
    if spec.checkpoint_every > 0 {
        exp = exp.checkpoint_every(spec.checkpoint_every)
            .checkpoint_dir(ckpt_dir);
    }
    match spec.algo {
        Algo::Fr => run_job_parallel(job, exp, jsonl, metrics),
        _ => run_job_sequential(job, exp, jsonl, metrics),
    }
}

/// FR's threaded deployment path (one worker per module).
fn run_job_parallel(job: &Job, exp: Experiment, jsonl: &std::path::Path,
                    metrics: &ServeMetrics) -> Result<JobState> {
    let spec = &job.spec;
    let mut ps = exp.spawn_parallel()?;
    let mut out = std::io::BufWriter::new(std::fs::File::create(jsonl)
        .with_context(|| format!("creating {}", jsonl.display()))?);
    let mut stopped = false;
    for step in 0..spec.steps {
        if job.stop.load(Ordering::Relaxed) {
            stopped = true;
            break;
        }
        let batch = ps.data.train_batch();
        let lr = ps.lr_at(step);
        let t0 = Instant::now();
        let stats = match ps.par.train_step(&batch, lr) {
            Ok(stats) => stats,
            Err(e) => {
                let _ = ps.par.shutdown();
                return Err(e.context(format!("train step {step}")));
            }
        };
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        metrics.train_step_ms.record(t0.elapsed());
        let line = obj(vec![
            ("step", num(step as f64)),
            ("loss", num(stats.loss as f64)),
            ("ms", num(ms)),
        ]).to_string_compact();
        // flush per line: clients tail this file while the job runs
        writeln!(out, "{line}")
            .and_then(|()| out.flush())
            .with_context(|| format!("writing {}", jsonl.display()))?;
        {
            let mut p = lock(&job.progress);
            p.step = step + 1;
            p.last_loss = stats.loss as f64;
        }
        if ps.should_checkpoint(step + 1) {
            if let Err(e) = ps.write_checkpoint() {
                let _ = ps.par.shutdown();
                return Err(e.context("writing job checkpoint"));
            }
        }
    }
    if !stopped {
        let eval = ps.data.test_batch(0);
        match ps.par.eval_batch(&eval) {
            Ok((loss, err)) => {
                lock(&job.progress).eval = Some((loss, err));
            }
            Err(e) => {
                let _ = ps.par.shutdown();
                return Err(e.context("final eval"));
            }
        }
    }
    ps.par.shutdown().context("fleet shutdown")?;
    Ok(if stopped { JobState::Stopped } else { JobState::Done })
}

/// Sequential path for every non-FR algorithm (BP/DDG/DNI/DGL/BackLink):
/// same NDJSON schema, stop semantics, checkpoint cadence and final eval
/// as the fleet path, driven through [`crate::experiment::Session`].
fn run_job_sequential(job: &Job, exp: Experiment, jsonl: &std::path::Path,
                      metrics: &ServeMetrics) -> Result<JobState> {
    let spec = &job.spec;
    let mut session = exp.session()?;
    let mut out = std::io::BufWriter::new(std::fs::File::create(jsonl)
        .with_context(|| format!("creating {}", jsonl.display()))?);
    let mut stopped = false;
    for step in 0..spec.steps {
        if job.stop.load(Ordering::Relaxed) {
            stopped = true;
            break;
        }
        let batch = session.data.train_batch();
        let lr = session.lr_at(step);
        let t0 = Instant::now();
        let stats = session.trainer.train_step(&batch, lr)
            .with_context(|| format!("train step {step}"))?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        metrics.train_step_ms.record(t0.elapsed());
        let line = obj(vec![
            ("step", num(step as f64)),
            ("loss", num(stats.loss as f64)),
            ("ms", num(ms)),
        ]).to_string_compact();
        // flush per line: clients tail this file while the job runs
        writeln!(out, "{line}")
            .and_then(|()| out.flush())
            .with_context(|| format!("writing {}", jsonl.display()))?;
        {
            let mut p = lock(&job.progress);
            p.step = step + 1;
            p.last_loss = stats.loss as f64;
        }
        if session.should_checkpoint(step + 1) {
            session.write_checkpoint(step + 1)
                .context("writing job checkpoint")?;
        }
    }
    if !stopped {
        let (loss, err) = session.trainer.stack()
            .eval(&mut session.data, 1)
            .context("final eval")?;
        lock(&job.progress).eval = Some((loss, err));
    }
    Ok(if stopped { JobState::Stopped } else { JobState::Done })
}
