//! `(method, path)` dispatch for the serve API.
//!
//! Every handler returns `Result<Response, ApiError>`; the single
//! [`handle`] entry point turns an `ApiError` into its JSON error
//! response, so no endpoint hand-rolls status bodies. Validation happens
//! here, synchronously, *before* a sample enters the batcher queue — the
//! batcher thread only ever sees inputs the [`crate::runtime::Packer`]
//! already accepted, which is why a 400 never costs a micro-batch slot.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::Packer;
use crate::runtime::spec::Manifest;
use crate::serve::batcher::Batcher;
use crate::serve::http::{status_text, Request, Response};
use crate::serve::jobs::JobRegistry;
use crate::serve::{json as body, ServeMetrics};
use crate::util::json::{arr, num, obj, s, Json};

/// Typed endpoint failures; each knows its HTTP status.
#[derive(Debug)]
pub enum ApiError {
    BadRequest(String),
    NotFound(String),
    MethodNotAllowed(&'static str),
    Unavailable(String),
    Internal(String),
}

impl ApiError {
    pub fn status(&self) -> u16 {
        match self {
            ApiError::BadRequest(_) => 400,
            ApiError::NotFound(_) => 404,
            ApiError::MethodNotAllowed(_) => 405,
            ApiError::Unavailable(_) => 503,
            ApiError::Internal(_) => 500,
        }
    }

    pub fn detail(&self) -> &str {
        match self {
            ApiError::BadRequest(d) | ApiError::NotFound(d)
            | ApiError::Unavailable(d) | ApiError::Internal(d) => d,
            ApiError::MethodNotAllowed(allow) => allow,
        }
    }

    pub fn to_response(&self) -> Response {
        let status = self.status();
        Response::json(status, &obj(vec![
            ("error", s(status_text(status))),
            ("detail", s(self.detail())),
        ]))
    }
}

/// Everything a request handler can reach — built once by
/// [`crate::serve::Server::bind`], shared across connection threads.
pub struct App {
    pub model: String,
    pub manifest: Manifest,
    pub packer: Packer,
    pub batcher: Batcher,
    pub jobs: JobRegistry,
    pub metrics: Arc<ServeMetrics>,
    pub started: Instant,
    pub max_batch: usize,
    pub max_wait_ms: u64,
}

/// Dispatch one request; never panics, never leaks an `Err` upward.
pub fn handle(app: &App, req: &Request) -> Response {
    let method = req.method.as_str();
    let result = match (method, req.path.as_str()) {
        ("GET", "/healthz") => health(app),
        ("GET", "/v1/metrics") => Ok(Response::json(200, &app.metrics.to_json())),
        ("POST", "/v1/predict") => predict(app, req),
        ("POST", "/v1/train-jobs") => submit_job(app, req),
        ("GET", "/v1/train-jobs") => Ok(Response::json(200, &app.jobs.list())),
        ("GET" | "POST" | "PUT" | "DELETE" | "HEAD",
         "/healthz" | "/v1/metrics" | "/v1/predict" | "/v1/train-jobs") => {
            Err(ApiError::MethodNotAllowed(match req.path.as_str() {
                "/v1/predict" | "/v1/train-jobs" => "use POST",
                _ => "use GET",
            }))
        }
        ("GET", path) if path.starts_with("/v1/train-jobs/") => job_route(app, path),
        (_, path) => Err(ApiError::NotFound(format!("no route for {path}"))),
    };
    result.unwrap_or_else(|e| e.to_response())
}

fn health(app: &App) -> Result<Response, ApiError> {
    Ok(Response::json(200, &obj(vec![
        ("ok", Json::Bool(true)),
        ("model", s(&app.model)),
        ("k", num(app.manifest.k as f64)),
        ("batch_capacity", num(app.packer.capacity() as f64)),
        ("max_batch", num(app.max_batch as f64)),
        ("max_wait_ms", num(app.max_wait_ms as f64)),
        ("uptime_ms", num(app.started.elapsed().as_secs_f64() * 1e3)),
    ])))
}

fn predict(app: &App, req: &Request) -> Result<Response, ApiError> {
    app.metrics.predict_requests.inc();
    let fail = |e: ApiError| {
        app.metrics.predict_errors.inc();
        e
    };
    let sample = body::decode_predict(&req.body)
        .map_err(|e| fail(ApiError::BadRequest(e)))?;
    app.packer.validate(&sample)
        .map_err(|e| fail(ApiError::BadRequest(e.to_string())))?;
    let rx = app.batcher.submit(sample)
        .map_err(|e| fail(ApiError::Unavailable(e.to_string())))?;
    // generous ceiling: max_wait plus worst-case forward passes queued ahead
    let deadline = Duration::from_millis(app.max_wait_ms) + Duration::from_secs(30);
    let result = rx.recv_timeout(deadline)
        .map_err(|_| fail(ApiError::Internal("predict timed out".to_string())))?;
    let done = result.map_err(|e| fail(ApiError::Internal(e)))?;
    Ok(Response::json(200, &obj(vec![
        ("model", s(&app.model)),
        // micro-batch size this sample rode in — lets clients (and the
        // parity test) observe coalescing
        ("batch", num(done.batch_size as f64)),
        ("logits", arr(done.logits.iter().map(|&v| num(v as f64)))),
    ])))
}

fn submit_job(app: &App, req: &Request) -> Result<Response, ApiError> {
    let spec = body::decode_train_job(&req.body).map_err(ApiError::BadRequest)?;
    let id = app.jobs.submit(spec)
        .map_err(|e| ApiError::Unavailable(format!("{e:#}")))?;
    Ok(Response::json(202, &obj(vec![
        ("id", num(id as f64)),
        ("state", s("running")),
        ("status_url", s(&format!("/v1/train-jobs/{id}"))),
        ("metrics_url", s(&format!("/v1/train-jobs/{id}/metrics"))),
    ])))
}

/// `/v1/train-jobs/<id>` and `/v1/train-jobs/<id>/metrics`.
fn job_route(app: &App, path: &str) -> Result<Response, ApiError> {
    let rest = &path["/v1/train-jobs/".len()..];
    let (id_part, tail) = match rest.split_once('/') {
        None => (rest, None),
        Some((id, "metrics")) => (id, Some("metrics")),
        Some(_) => return Err(ApiError::NotFound(format!("no route for {path}"))),
    };
    let id: usize = id_part.parse()
        .map_err(|_| ApiError::BadRequest(format!("bad job id {id_part:?}")))?;
    match tail {
        None => app.jobs.get(id)
            .map(|status| Response::json(200, &status))
            .ok_or_else(|| ApiError::NotFound(format!("no job {id}"))),
        Some(_) => app.jobs.read_metrics(id)
            .map(|bytes| Response::ndjson(200, bytes))
            .ok_or_else(|| ApiError::NotFound(format!("no job {id}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiment::Experiment;

    /// A real App over the tiny registry model — exercises the same
    /// construction path as `Server::bind`, minus the listener.
    fn test_app(tag: &str) -> App {
        let exp = Experiment::new("mlp_tiny").k(2).threads(1).seed(0);
        let manifest = exp.manifest().expect("mlp_tiny manifest");
        let packer = Packer::new(&manifest).expect("packer");
        let metrics = Arc::new(ServeMetrics::default());
        let batcher = Batcher::spawn(
            exp, None, 4, Duration::from_millis(1), Arc::clone(&metrics))
            .expect("batcher");
        let dir = std::env::temp_dir()
            .join(format!("fr-router-test-{}-{tag}", std::process::id()));
        let jobs = JobRegistry::new(dir, Arc::clone(&metrics)).expect("jobs");
        App {
            model: "mlp_tiny".to_string(),
            manifest,
            packer,
            batcher,
            jobs,
            metrics,
            started: Instant::now(),
            max_batch: 4,
            max_wait_ms: 1,
        }
    }

    fn get(path: &str) -> Request {
        Request {
            method: "GET".to_string(),
            path: path.to_string(),
            headers: Vec::new(),
            body: Vec::new(),
        }
    }

    #[test]
    fn handle_dispatches_and_maps_errors() {
        let app = test_app("dispatch");
        let ok = handle(&app, &get("/healthz"));
        assert_eq!(ok.status, 200);
        let body = Json::parse(std::str::from_utf8(&ok.body).unwrap()).unwrap();
        assert_eq!(body.get("model").and_then(Json::as_str), Some("mlp_tiny"));

        assert_eq!(handle(&app, &get("/nope")).status, 404);
        assert_eq!(handle(&app, &get("/v1/predict")).status, 405);
        assert_eq!(handle(&app, &get("/v1/train-jobs/oops")).status, 400);
        app.batcher.shutdown();
    }

    #[test]
    fn detail_carries_the_message_verbatim() {
        let e = ApiError::Unavailable("predict queue full (64 waiting)".into());
        assert_eq!(e.detail(), "predict queue full (64 waiting)");
        assert_eq!(ApiError::MethodNotAllowed("use GET").detail(), "use GET");
    }

    #[test]
    fn api_errors_map_to_statuses() {
        assert_eq!(ApiError::BadRequest(String::new()).status(), 400);
        assert_eq!(ApiError::NotFound(String::new()).status(), 404);
        assert_eq!(ApiError::MethodNotAllowed("use GET").status(), 405);
        assert_eq!(ApiError::Unavailable(String::new()).status(), 503);
        assert_eq!(ApiError::Internal(String::new()).status(), 500);
    }

    #[test]
    fn error_response_is_json_with_detail() {
        let resp = ApiError::BadRequest("tokens out of range".to_string())
            .to_response();
        assert_eq!(resp.status, 400);
        let json = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(json.get("error").and_then(Json::as_str), Some("Bad Request"));
        assert_eq!(json.get("detail").and_then(Json::as_str),
                   Some("tokens out of range"));
    }
}
