//! Runtime-layer integration: numerical parity of the AOT artifacts with
//! ground truth, across every built config. These catch interchange-format
//! or marshaling regressions.

use features_replay::data::DataSource;
use features_replay::metrics::xent_and_acc;
use features_replay::runtime::{DType, Engine, Manifest, ModuleRuntime, Tensor};

fn root() -> std::path::PathBuf {
    features_replay::default_artifacts_root()
}

fn have(cfg: &str) -> bool {
    let ok = root().join(cfg).exists();
    if !ok {
        eprintln!("skipping: {cfg} not built (make artifacts)");
    }
    ok
}

/// Loss-head loss must equal a Rust-side cross-entropy on its own logits.
#[test]
fn loss_head_agrees_with_host_xent() {
    for cfg in ["mlp_tiny_k4", "resnet_s_k2", "transformer_tiny_k4"] {
        if !have(cfg) {
            continue;
        }
        let m = Manifest::load(&root().join(cfg)).unwrap();
        let engine = Engine::cpu().unwrap();
        let mut data = DataSource::for_manifest(&m, 9).unwrap();
        let batch = data.train_batch();

        let mut h = batch.input.clone();
        for k in 0..m.k - 1 {
            let mm = ModuleRuntime::load(&engine, &m, k).unwrap();
            h = mm.forward(&h).unwrap();
        }
        let last = ModuleRuntime::load(&engine, &m, m.k - 1).unwrap();
        let out = last.loss_backward(&h, &batch.labels).unwrap();
        let (host_loss, _) = xent_and_acc(&out.logits, &batch.labels);
        let diff = (out.loss as f64 - host_loss).abs();
        assert!(diff < 1e-4, "{cfg}: artifact loss {} vs host {host_loss}",
                out.loss);
    }
}

/// Gradient check: artifact bwd ~= central finite differences.
#[test]
fn bwd_matches_finite_differences() {
    if !have("mlp_tiny_k4") {
        return;
    }
    let m = Manifest::load(&root().join("mlp_tiny_k4")).unwrap();
    let engine = Engine::cpu().unwrap();
    let last = m.k - 1;
    let mut module = ModuleRuntime::load(&engine, &m, last).unwrap();
    let mut data = DataSource::for_manifest(&m, 13).unwrap();
    let batch = data.train_batch();
    let mut h = batch.input.clone();
    for k in 0..last {
        let mm = ModuleRuntime::load(&engine, &m, k).unwrap();
        h = mm.forward(&h).unwrap();
    }

    let base_grads = module.loss_backward(&h, &batch.labels).unwrap().grads;

    let eps = 1e-2f32;
    for i in [0usize, 7, 31, 64, 100] {
        let orig = module.params[0].f32s()[i];
        module.params[0].f32s_mut()[i] = orig + eps;
        let lp = module.loss_backward(&h, &batch.labels).unwrap().loss;
        module.params[0].f32s_mut()[i] = orig - eps;
        let lm = module.loss_backward(&h, &batch.labels).unwrap().loss;
        module.params[0].f32s_mut()[i] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        let an = base_grads[0].f32s()[i];
        assert!((fd - an).abs() < 2e-2 + 0.05 * an.abs(),
                "coord {i}: finite-diff {fd} vs artifact {an}");
    }
}

/// Every built manifest loads, chains shapes, and runs one forward pass.
#[test]
fn all_built_configs_forward_cleanly() {
    let Ok(entries) = std::fs::read_dir(root()) else {
        eprintln!("skipping: artifacts root missing");
        return;
    };
    let mut tested = 0;
    for e in entries.flatten() {
        let dir = e.path();
        if !dir.join("manifest.json").exists() {
            continue;
        }
        let m = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let mut h = Tensor::zeros(&m.input_shape, m.input_dtype);
        for k in 0..m.k {
            let mm = ModuleRuntime::load(&engine, &m, k).unwrap();
            assert_eq!(h.shape, mm.spec.in_shape, "{dir:?} module {k}");
            h = mm.forward(&h).unwrap();
        }
        assert_eq!(h.shape, m.logits_shape, "{dir:?} final logits");
        tested += 1;
    }
    eprintln!("forward-chained {tested} artifact configs");
    assert!(tested > 0, "no artifact configs found — run `make artifacts`");
}

/// Param dumps load for every module of every built config and are finite.
#[test]
fn param_dumps_complete() {
    let Ok(entries) = std::fs::read_dir(root()) else { return };
    for e in entries.flatten() {
        let dir = e.path();
        if !dir.join("manifest.json").exists() {
            continue;
        }
        let m = Manifest::load(&dir).unwrap();
        for (k, spec) in m.modules.iter().enumerate() {
            for (i, shape) in spec.param_shapes.iter().enumerate() {
                let t = Tensor::from_f32_file(
                    &m.param_path(&format!("module{k}"), i), shape.clone())
                    .unwrap_or_else(|err| panic!("{dir:?} module{k} p{i}: {err}"));
                assert!(t.f32s().iter().all(|x| x.is_finite()),
                        "{dir:?} module{k} p{i}: non-finite init");
            }
        }
    }
}

/// Transformer artifacts accept i32 tokens and reject wrong-shape input.
#[test]
fn transformer_input_dtype_enforced() {
    if !have("transformer_tiny_k4") {
        return;
    }
    let m = Manifest::load(&root().join("transformer_tiny_k4")).unwrap();
    assert_eq!(m.input_dtype, DType::I32);
    let engine = Engine::cpu().unwrap();
    let m0 = ModuleRuntime::load(&engine, &m, 0).unwrap();
    let good = Tensor::zeros(&m.input_shape, DType::I32);
    assert!(m0.forward(&good).is_ok());
    let bad = Tensor::zeros(&[2, 2], DType::F32);
    assert!(m0.forward(&bad).is_err());
}
