//! Runtime-layer integration: numerical parity of the execution backends
//! with ground truth. The native-backend tests run everywhere (procedural
//! manifests, no artifacts); the AOT-artifact tests live behind the `pjrt`
//! feature and skip when artifacts are absent.

use features_replay::data::DataSource;
use features_replay::metrics::xent_and_acc;
use features_replay::runtime::{Engine, ModuleRuntime, NativeMlpSpec, Tensor};

/// Native loss-head loss must equal a Rust-side cross-entropy on its own
/// logits (same formula as the eval path).
#[test]
fn native_loss_head_agrees_with_host_xent() {
    let m = NativeMlpSpec::tiny(4).manifest().unwrap();
    let engine = Engine::native();
    let mut data = DataSource::for_manifest(&m, 9).unwrap();
    let batch = data.train_batch();

    let mut h = batch.input.clone();
    for k in 0..m.k - 1 {
        let mm = ModuleRuntime::load(&engine, &m, k).unwrap();
        h = mm.forward(&h).unwrap();
    }
    let last = ModuleRuntime::load(&engine, &m, m.k - 1).unwrap();
    let out = last.loss_backward(&h, &batch.labels).unwrap();
    let (host_loss, _) = xent_and_acc(&out.logits, &batch.labels);
    let diff = (out.loss as f64 - host_loss).abs();
    assert!(diff < 1e-4, "native loss {} vs host {host_loss}", out.loss);
}

/// Every native config forward-chains with consistent shapes at several K.
#[test]
fn native_configs_forward_cleanly_at_all_k() {
    for k in 1..=4 {
        let m = NativeMlpSpec::tiny(k).manifest().unwrap();
        let engine = Engine::native();
        let mut h = Tensor::zeros(&m.input_shape, m.input_dtype);
        for j in 0..m.k {
            let mm = ModuleRuntime::load(&engine, &m, j).unwrap();
            assert_eq!(h.shape, mm.spec.in_shape, "k={k} module {j}");
            h = mm.forward(&h).unwrap();
        }
        assert_eq!(h.shape, m.logits_shape, "k={k} final logits");
    }
}

/// Backward is a pure function of (params, input, delta): running it twice
/// yields bit-identical gradients (no hidden state in the recompute path).
#[test]
fn native_backward_is_deterministic() {
    let m = NativeMlpSpec::tiny(3).manifest().unwrap();
    let engine = Engine::native();
    let mm = ModuleRuntime::load(&engine, &m, 1).unwrap();
    let mut data = DataSource::for_manifest(&m, 13).unwrap();
    let batch = data.train_batch();
    let m0 = ModuleRuntime::load(&engine, &m, 0).unwrap();
    let h = m0.forward(&batch.input).unwrap();
    let delta = Tensor::from_f32(
        mm.spec.out_shape.clone(),
        (0..mm.spec.out_shape.iter().product::<usize>())
            .map(|i| (i as f32 * 0.37).sin())
            .collect(),
    ).unwrap();
    let (g1, d1) = mm.backward(&h, &delta).unwrap();
    let (g2, d2) = mm.backward(&h, &delta).unwrap();
    for (a, b) in g1.iter().zip(&g2) {
        assert_eq!(a.f32s(), b.f32s());
    }
    assert_eq!(d1.unwrap().f32s(), d2.unwrap().f32s());
}

/// Native init is procedural and deterministic: two loads of the same module
/// carry identical parameters (what makes worker fleets bit-compatible).
#[test]
fn native_param_init_is_reproducible() {
    let m = NativeMlpSpec::tiny(2).manifest().unwrap();
    let engine = Engine::native();
    let a = ModuleRuntime::load(&engine, &m, 0).unwrap();
    let b = ModuleRuntime::load(&engine, &m, 0).unwrap();
    assert_eq!(a.params.len(), b.params.len());
    for (x, y) in a.params.iter().zip(b.params.iter()) {
        assert_eq!(x.f32s(), y.f32s());
        assert!(x.f32s().iter().all(|v| v.is_finite()));
    }
}

/// AOT-artifact tests (PJRT backend). Skip when artifacts are absent so
/// `cargo test --features pjrt` stays runnable on a fresh checkout.
#[cfg(feature = "pjrt")]
mod pjrt_artifacts {
    use super::*;
    use features_replay::runtime::{DType, Manifest};

    fn root() -> std::path::PathBuf {
        features_replay::default_artifacts_root()
    }

    fn have(cfg: &str) -> bool {
        let ok = root().join(cfg).exists();
        if !ok {
            eprintln!("skipping: {cfg} not built (make artifacts)");
        }
        ok
    }

    #[test]
    fn loss_head_agrees_with_host_xent() {
        for cfg in ["mlp_tiny_k4", "resnet_s_k2", "transformer_tiny_k4"] {
            if !have(cfg) {
                continue;
            }
            let m = Manifest::load(&root().join(cfg)).unwrap();
            let engine = Engine::pjrt_cpu().unwrap();
            let mut data = DataSource::for_manifest(&m, 9).unwrap();
            let batch = data.train_batch();

            let mut h = batch.input.clone();
            for k in 0..m.k - 1 {
                let mm = ModuleRuntime::load(&engine, &m, k).unwrap();
                h = mm.forward(&h).unwrap();
            }
            let last = ModuleRuntime::load(&engine, &m, m.k - 1).unwrap();
            let out = last.loss_backward(&h, &batch.labels).unwrap();
            let (host_loss, _) = xent_and_acc(&out.logits, &batch.labels);
            let diff = (out.loss as f64 - host_loss).abs();
            assert!(diff < 1e-4, "{cfg}: artifact loss {} vs host {host_loss}",
                    out.loss);
        }
    }

    #[test]
    fn param_dumps_complete() {
        let Ok(entries) = std::fs::read_dir(root()) else { return };
        for e in entries.flatten() {
            let dir = e.path();
            if !dir.join("manifest.json").exists() {
                continue;
            }
            let m = Manifest::load(&dir).unwrap();
            for (k, spec) in m.modules.iter().enumerate() {
                for (i, shape) in spec.param_shapes.iter().enumerate() {
                    let t = Tensor::from_f32_file(
                        &m.param_path(&format!("module{k}"), i), shape.clone())
                        .unwrap_or_else(|err| panic!("{dir:?} module{k} p{i}: {err}"));
                    assert!(t.f32s().iter().all(|x| x.is_finite()),
                            "{dir:?} module{k} p{i}: non-finite init");
                }
            }
        }
    }

    #[test]
    fn transformer_input_dtype_enforced() {
        if !have("transformer_tiny_k4") {
            return;
        }
        let m = Manifest::load(&root().join("transformer_tiny_k4")).unwrap();
        assert_eq!(m.input_dtype, DType::I32);
        let engine = Engine::pjrt_cpu().unwrap();
        let m0 = ModuleRuntime::load(&engine, &m, 0).unwrap();
        let good = Tensor::zeros(&m.input_shape, DType::I32);
        assert!(m0.forward(&good).is_ok());
        let bad = Tensor::zeros(&[2, 2], DType::F32);
        assert!(m0.forward(&bad).is_err());
    }
}
