//! Property-based tests (mini-proptest) over the coordinator's pure
//! invariants — replay buffers, optimizer algebra, JSON round-trips, the
//! pipeline simulator, the memory model's monotonicity — and the native
//! kernels' parallelism contract: every pool-partitioned `*_p` kernel is
//! **bitwise identical** to its serial twin across randomized shapes,
//! thread counts, and `min_work` thresholds (including the degenerate
//! shapes — empty outputs, single rows/columns, `seq = 1`, one sequence
//! group — where partition bookkeeping is most likely to slip).

use features_replay::checkpoint::{Checkpoint, Meta, ModuleState, RingState};
use features_replay::coordinator::history::ReplayBuffer;
use features_replay::coordinator::pipeline_sim::{
    bp_data_parallel_ms, bp_iteration_ms, decoupled_iteration_ms, CommModel,
    MeasuredCosts,
};
use features_replay::coordinator::{self, ModuleStack, TrainConfig, Trainer};
use features_replay::data::DataSource;
use features_replay::optim::SgdMomentum;
use features_replay::runtime::native::kernels;
use features_replay::runtime::pool::resolve_threads;
use features_replay::runtime::{blocked, DType, Engine, NativeLmSpec, Precision, Tensor};
use features_replay::testing::check;
use features_replay::util::json::Json;

/// Bitwise slice equality — the pool determinism contract is `to_bits`
/// equality, stricter than `==` (distinguishes -0.0 from 0.0 and never
/// equates NaNs away).
fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn replay_buffer_returns_exact_lag() {
    check("replay_lag", 200, |g| {
        let cap = g.usize_in(1, 8);
        let pushes = g.usize_in(0, 40);
        let mut buf = ReplayBuffer::new(cap, &[1], DType::F32);
        for i in 0..pushes {
            buf.push(Tensor::from_f32(vec![1], vec![i as f32 + 1.0]).unwrap());
        }
        let lag = g.usize_in(0, cap - 1);
        let got = buf.stale(lag).f32s()[0];
        let want = if pushes > lag { (pushes - lag) as f32 } else { 0.0 };
        if got == want {
            Ok(())
        } else {
            Err(format!("cap={cap} pushes={pushes} lag={lag}: got {got}, want {want}"))
        }
    });
}

#[test]
fn replay_buffer_warmup_consistent_with_reads() {
    check("replay_warmup", 200, |g| {
        let cap = g.usize_in(1, 6);
        let mut buf = ReplayBuffer::new(cap, &[1], DType::F32);
        for _ in 0..g.usize_in(0, 20) {
            buf.push(Tensor::from_f32(vec![1], vec![1.0]).unwrap());
        }
        for lag in 0..cap {
            let warmed = buf.warmed(lag);
            let nonzero = buf.stale(lag).f32s()[0] != 0.0;
            if warmed != nonzero {
                return Err(format!("cap={cap} lag={lag}: warmed={warmed} nonzero={nonzero}"));
            }
        }
        Ok(())
    });
}

#[test]
fn sgd_without_momentum_is_linear_in_lr() {
    check("sgd_linear", 100, |g| {
        let n = g.usize_in(1, 32);
        let w0 = g.vec_f32(n, -1.0, 1.0);
        let gr = g.vec_f32(n, -1.0, 1.0);
        let lr = g.f32_in(0.001, 0.5);

        let run = |mult: f32| -> Vec<f32> {
            let mut p = vec![Tensor::from_f32(vec![n], w0.clone()).unwrap()];
            let gt = vec![Tensor::from_f32(vec![n], gr.clone()).unwrap()];
            let mut opt = SgdMomentum::new(&p, 0.0, 0.0);
            opt.step(&mut p, &gt, lr * mult).unwrap();
            p[0].f32s().to_vec()
        };
        let w1 = run(1.0);
        let w2 = run(2.0);
        // (w0 - w2) == 2 * (w0 - w1)
        for i in 0..n {
            let d1 = w0[i] - w1[i];
            let d2 = w0[i] - w2[i];
            if (d2 - 2.0 * d1).abs() > 1e-5 {
                return Err(format!("i={i}: d1={d1} d2={d2}"));
            }
        }
        Ok(())
    });
}

#[test]
fn sgd_momentum_matches_reference_recurrence() {
    check("sgd_momentum_ref", 50, |g| {
        let steps = g.usize_in(1, 10);
        let mu = g.f32_in(0.0, 0.99);
        let wd = g.f32_in(0.0, 0.01);
        let lr = g.f32_in(0.001, 0.1);
        let g0 = g.f32_in(-1.0, 1.0);

        let mut p = vec![Tensor::from_f32(vec![1], vec![1.0]).unwrap()];
        let gt = vec![Tensor::from_f32(vec![1], vec![g0]).unwrap()];
        let mut opt = SgdMomentum::new(&p, mu, wd);

        // scalar reference recurrence
        let (mut w, mut v) = (1.0f32, 0.0f32);
        for _ in 0..steps {
            opt.step(&mut p, &gt, lr).unwrap();
            let grad = g0 + wd * w;
            v = mu * v + grad;
            w -= lr * v;
        }
        let got = p[0].f32s()[0];
        if (got - w).abs() < 1e-4 {
            Ok(())
        } else {
            Err(format!("got {got}, reference {w}"))
        }
    });
}

#[test]
fn json_roundtrips_generated_documents() {
    check("json_roundtrip", 150, |g| {
        fn gen_value(g: &mut features_replay::testing::Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.usize_in(0, 1) == 1),
                2 => Json::Num((g.f32_in(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-\"quoted\"\n", g.usize_in(0, 999))),
                4 => Json::Arr((0..g.usize_in(0, 4))
                    .map(|_| gen_value(g, depth - 1))
                    .collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..g.usize_in(0, 4) {
                        m.insert(format!("k{i}"), gen_value(g, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen_value(g, 3);
        let text = v.to_string_pretty();
        match Json::parse(&text) {
            Ok(back) if back == v => Ok(()),
            Ok(back) => Err(format!("roundtrip mismatch:\n{v:?}\nvs\n{back:?}")),
            Err(e) => Err(format!("reparse failed: {e} on {text}")),
        }
    });
}

#[test]
fn decoupled_never_slower_than_locked_bp() {
    check("fr_le_bp", 200, |g| {
        let k = g.usize_in(1, 8);
        let costs = MeasuredCosts {
            fwd_ms: g.vec_f32(k, 0.0, 50.0).iter().map(|&x| x as f64).collect(),
            bwd_ms: g.vec_f32(k, 0.0, 50.0).iter().map(|&x| x as f64).collect(),
            aux_ms: vec![0.0; k],
            boundary_bytes: g.vec_usize(k.saturating_sub(1), 0, 1_000_000),
            param_bytes: 0,
        };
        let comm = CommModel::default();
        let bp = bp_iteration_ms(&costs, &comm);
        let fr = decoupled_iteration_ms(&costs, &comm);
        // FR replaces sum(bwd) + down-transfers with max(bwd): never slower
        if fr <= bp + 1e-9 {
            Ok(())
        } else {
            Err(format!("fr {fr} > bp {bp} at k={k}"))
        }
    });
}

#[test]
fn data_parallel_monotone_compute_term() {
    check("dp_compute", 100, |g| {
        let k = g.usize_in(1, 6);
        let costs = MeasuredCosts {
            fwd_ms: g.vec_f32(k, 1.0, 20.0).iter().map(|&x| x as f64).collect(),
            bwd_ms: g.vec_f32(k, 1.0, 20.0).iter().map(|&x| x as f64).collect(),
            aux_ms: vec![0.0; k],
            boundary_bytes: vec![0; k.saturating_sub(1)],
            param_bytes: 0, // no allreduce -> pure compute scaling
        };
        let comm = CommModel { latency_ms: 0.0, bytes_per_ms: 1e30 };
        let mut prev = f64::INFINITY;
        for n in 1..=4 {
            let t = bp_data_parallel_ms(&costs, &comm, n);
            if t > prev + 1e-9 {
                return Err(format!("dp time increased at n={n}"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn tensor_clone_shares_until_write_then_detaches() {
    check("tensor_cow", 100, |g| {
        let rank = g.usize_in(1, 4);
        let shape = g.vec_usize(rank, 1, 8);
        let n: usize = shape.iter().product();
        let data = g.vec_f32(n, -100.0, 100.0);
        let t = Tensor::from_f32(shape.clone(), data.clone()).unwrap();
        let mut c = t.clone();
        if !c.shares_storage(&t) {
            return Err("clone must share storage".to_string());
        }
        let i = g.usize_in(0, n - 1);
        c.f32s_mut()[i] += 1.0;
        if c.shares_storage(&t) {
            return Err("write must detach the clone".to_string());
        }
        if t.f32s() != &data[..] {
            return Err("original mutated through a clone".to_string());
        }
        Ok(())
    });
}

// ---- kernel parity: every `*_p` kernel == its serial twin, bitwise ------

#[test]
fn pool_matmul_family_bitwise_parity() {
    check("matmul_family_parity", 100, |g| {
        let pool = g.pool();
        let tag = format!("threads={} min_work={}", pool.threads(), pool.min_work());
        let (m, k, n) = (g.dim(64), g.dim(64), g.dim(64));
        let a = g.vec_f32(m * k, -1.0, 1.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        if !bits_eq(&kernels::matmul_p(&pool, &a, &b, m, k, n),
                    &kernels::matmul(&a, &b, m, k, n)) {
            return Err(format!("matmul {m}x{k}x{n} {tag}"));
        }
        let bt = g.vec_f32(n * k, -1.0, 1.0);
        if !bits_eq(&kernels::matmul_nt_p(&pool, &a, &bt, m, k, n),
                    &kernels::matmul_nt(&a, &bt, m, k, n)) {
            return Err(format!("matmul_nt {m}x{k}x{n} {tag}"));
        }
        // tn reads `a` as (rows=m, cols=k); exact zeros exercise the
        // ReLU-skip on both sides of every chunk boundary
        let mut az = a;
        for v in az.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let dy = g.vec_f32(m * n, -1.0, 1.0);
        if !bits_eq(&kernels::matmul_tn_p(&pool, &az, &dy, m, k, n),
                    &kernels::matmul_tn(&az, &dy, m, k, n)) {
            return Err(format!("matmul_tn {m}x{k}x{n} {tag}"));
        }
        Ok(())
    });
}

/// The cache-blocked rewrite's core claim: blocking, B-panel packing and
/// register tiling are *layout* transformations — every output element
/// keeps its single scalar accumulator chain over `p` ascending, so the
/// blocked kernels are bitwise identical to the naive loops they replaced.
/// `k` ranges past [`blocked::KC`] so the k-panel loop takes more than one
/// panel (the store/reload seam between panels is where reassociation
/// would first show up).
#[test]
fn blocked_matmul_variants_bitwise_match_naive() {
    check("blocked_vs_naive", 60, |g| {
        let (m, n) = (g.dim(24), g.dim(40));
        let k = g.usize_in(1, blocked::KC + 40);
        let a = g.vec_f32(m * k, -1.0, 1.0);
        let b = g.vec_f32(k * n, -1.0, 1.0);
        let naive = kernels::matmul_naive(&a, &b, m, k, n);
        if !bits_eq(&kernels::matmul_blocked_scalar(&a, &b, m, k, n), &naive) {
            return Err(format!("matmul_blocked_scalar {m}x{k}x{n}"));
        }
        if !bits_eq(&kernels::matmul(&a, &b, m, k, n), &naive) {
            return Err(format!("matmul_blocked_simd {m}x{k}x{n}"));
        }
        let bt = g.vec_f32(n * k, -1.0, 1.0);
        if !bits_eq(&kernels::matmul_nt(&a, &bt, m, k, n),
                    &kernels::matmul_nt_naive(&a, &bt, m, k, n)) {
            return Err(format!("matmul_nt_blocked {m}x{k}x{n}"));
        }
        // tn: exact zeros exercise the ReLU-skip against the unrolled lanes
        let mut az = a;
        for v in az.iter_mut().step_by(3) {
            *v = 0.0;
        }
        let dy = g.vec_f32(m * n, -1.0, 1.0);
        if !bits_eq(&kernels::matmul_tn(&az, &dy, m, k, n),
                    &kernels::matmul_tn_naive(&az, &dy, m, k, n)) {
            return Err(format!("matmul_tn_blocked {m}x{k}x{n}"));
        }
        Ok(())
    });
}

/// The one kernel allowed to reassociate: `matmul_nt_fast` splits each dot
/// product into [`blocked::FAST_LANES`] interleaved partial sums. The
/// `Fast` tier's contract is (a) still fully deterministic — the split
/// depends only on `k`, so the pool-partitioned result is bitwise equal to
/// the serial one at every thread count — and (b) within the documented
/// bound `|fast - exact| <= 2 k eps sum_i |a_i b_i|` of the exact chain,
/// with the bound evaluated in f64.
#[test]
fn matmul_nt_fast_is_thread_deterministic_and_ulp_bounded() {
    check("nt_fast_det_ulp", 60, |g| {
        let pool = g.pool();
        let tag = format!("threads={} min_work={}", pool.threads(), pool.min_work());
        let (m, n) = (g.dim(16), g.dim(16));
        let k = g.usize_in(1, 2 * blocked::FAST_LANES * 8);
        let a = g.vec_f32(m * k, -1.0, 1.0);
        let b = g.vec_f32(n * k, -1.0, 1.0);
        let fast = kernels::matmul_nt_fast(&a, &b, m, k, n);
        if !bits_eq(&kernels::matmul_nt_p_prec(&pool, Precision::Fast, &a, &b, m, k, n),
                    &fast) {
            return Err(format!("Fast pool result diverged from serial {m}x{k}x{n} {tag}"));
        }
        // and Exact through the same entry point is still the naive chain
        if !bits_eq(&kernels::matmul_nt_p_prec(&pool, Precision::Exact, &a, &b, m, k, n),
                    &kernels::matmul_nt_naive(&a, &b, m, k, n)) {
            return Err(format!("Exact pool result diverged from naive {m}x{k}x{n} {tag}"));
        }
        let exact = kernels::matmul_nt_naive(&a, &b, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let mut mag = 0.0f64;
                for p in 0..k {
                    mag += (a[i * k + p] as f64 * b[j * k + p] as f64).abs();
                }
                let bound = 2.0 * k as f64 * f32::EPSILON as f64 * mag;
                let diff = (fast[i * n + j] as f64 - exact[i * n + j] as f64).abs();
                if diff > bound {
                    return Err(format!(
                        "({i},{j}) of {m}x{k}x{n}: |fast-exact| = {diff:e} > {bound:e}"));
                }
            }
        }
        Ok(())
    });
}

/// The fused conv forward (task-local im2col scratch feeding the blocked
/// matmul directly) must be bitwise identical to the unfused pipeline it
/// replaced — materialize cols with `im2col_p`, then `matmul_p` — across
/// randomized shapes, paddings, and pool configurations.
#[test]
fn conv2d_fused_bitwise_matches_unfused() {
    check("conv_fused_parity", 60, |g| {
        let pool = g.pool();
        let (b, cin, cout) = (g.dim1(4), g.dim1(4), g.dim1(5));
        let k = g.usize_in(1, 3);
        let stride = g.usize_in(1, 2);
        let pad = g.usize_in(0, 1);
        let hw = g.usize_in(k.saturating_sub(2 * pad).max(1), 8);
        let tag = format!("b{b} hw{hw} cin{cin} cout{cout} k{k} s{stride} p{pad} \
                           threads={} min_work={}", pool.threads(), pool.min_work());
        let x = g.vec_f32(b * hw * hw * cin, -1.0, 1.0);
        let w = g.vec_f32(k * k * cin * cout, -1.0, 1.0);
        let fused = kernels::conv2d_fused_p(&pool, &x, &w, b, hw, cin, k, stride, pad, cout);
        let ohw = (hw + 2 * pad - k) / stride + 1;
        let cols = kernels::im2col_p(&pool, &x, b, hw, cin, k, stride, pad);
        let unfused = kernels::matmul_p(&pool, &cols, &w,
                                        b * ohw * ohw, k * k * cin, cout);
        if bits_eq(&fused, &unfused) {
            Ok(())
        } else {
            Err(format!("conv2d_fused {tag}"))
        }
    });
}

#[test]
fn pool_im2col_col2im_bitwise_parity() {
    check("im2col_parity", 100, |g| {
        let pool = g.pool();
        let (b, c) = (g.dim1(5), g.dim1(4));
        let k = g.usize_in(1, 3);
        let stride = g.usize_in(1, 2);
        let pad = g.usize_in(0, 1);
        // the window must fit the padded image at least once
        let hw = g.usize_in(k.saturating_sub(2 * pad).max(1), 8);
        let tag = format!("b{b} hw{hw} c{c} k{k} s{stride} p{pad} threads={} \
                           min_work={}", pool.threads(), pool.min_work());
        let x = g.vec_f32(b * hw * hw * c, -1.0, 1.0);
        if !bits_eq(&kernels::im2col_p(&pool, &x, b, hw, c, k, stride, pad),
                    &kernels::im2col(&x, b, hw, c, k, stride, pad)) {
            return Err(format!("im2col {tag}"));
        }
        let ohw = (hw + 2 * pad - k) / stride + 1;
        let cols = g.vec_f32(b * ohw * ohw * k * k * c, -1.0, 1.0);
        if !bits_eq(&kernels::col2im_p(&pool, &cols, b, hw, c, k, stride, pad),
                    &kernels::col2im(&cols, b, hw, c, k, stride, pad)) {
            return Err(format!("col2im {tag}"));
        }
        Ok(())
    });
}

#[test]
fn pool_attention_kernels_bitwise_parity() {
    check("attention_parity", 100, |g| {
        let pool = g.pool();
        // dim1 biases toward 1, so seq = 1, a single group (b = seq), and
        // d = 1 all occur across the run
        let (groups, seq, d) = (g.dim1(6), g.dim1(8), g.dim1(8));
        let tag = format!("g{groups} seq{seq} d{d} threads={} min_work={}",
                          pool.threads(), pool.min_work());
        let scale = 1.0 / (d as f32).sqrt();
        let q = g.vec_f32(groups * seq * d, -1.0, 1.0);
        let k = g.vec_f32(groups * seq * d, -1.0, 1.0);
        let v = g.vec_f32(groups * seq * d, -1.0, 1.0);
        let probs = kernels::attn_scores(&q, &k, groups, seq, d, scale);
        if !bits_eq(&kernels::attn_scores_p(&pool, &q, &k, groups, seq, d, scale),
                    &probs) {
            return Err(format!("attn_scores {tag}"));
        }
        if !bits_eq(&kernels::attn_context_p(&pool, &probs, &v, groups, seq, d),
                    &kernels::attn_context(&probs, &v, groups, seq, d)) {
            return Err(format!("attn_context {tag}"));
        }
        let dctx = g.vec_f32(groups * seq * d, -1.0, 1.0);
        let (da, dv) = kernels::attn_context_bwd(&probs, &v, &dctx, groups, seq, d);
        let (da_p, dv_p) =
            kernels::attn_context_bwd_p(&pool, &probs, &v, &dctx, groups, seq, d);
        if !bits_eq(&da_p, &da) || !bits_eq(&dv_p, &dv) {
            return Err(format!("attn_context_bwd {tag}"));
        }
        let (dq, dk) =
            kernels::attn_scores_bwd(&probs, &da, &q, &k, groups, seq, d, scale);
        let (dq_p, dk_p) =
            kernels::attn_scores_bwd_p(&pool, &probs, &da, &q, &k, groups, seq, d, scale);
        if !bits_eq(&dq_p, &dq) || !bits_eq(&dk_p, &dk) {
            return Err(format!("attn_scores_bwd {tag}"));
        }
        Ok(())
    });
}

#[test]
fn pool_pooling_kernels_bitwise_parity() {
    check("pooling_parity", 100, |g| {
        let pool = g.pool();
        let (b, c) = (g.dim1(5), g.dim1(4));
        let kernel = g.usize_in(1, 3);
        let stride = g.usize_in(1, 2);
        let hw = g.usize_in(kernel, 8);
        let tag = format!("b{b} hw{hw} c{c} k{kernel} s{stride} threads={} \
                           min_work={}", pool.threads(), pool.min_work());
        let x = g.vec_f32(b * hw * hw * c, -1.0, 1.0);
        if !bits_eq(&kernels::avgpool_p(&pool, &x, b, hw, c, kernel, stride),
                    &kernels::avgpool(&x, b, hw, c, kernel, stride)) {
            return Err(format!("avgpool {tag}"));
        }
        let ohw = (hw - kernel) / stride + 1;
        let dy = g.vec_f32(b * ohw * ohw * c, -1.0, 1.0);
        if !bits_eq(&kernels::avgpool_bwd_p(&pool, &dy, b, hw, c, kernel, stride),
                    &kernels::avgpool_bwd(&dy, b, hw, c, kernel, stride)) {
            return Err(format!("avgpool_bwd {tag}"));
        }
        if !bits_eq(&kernels::global_avgpool_p(&pool, &x, b, hw, c),
                    &kernels::global_avgpool(&x, b, hw, c)) {
            return Err(format!("global_avgpool {tag}"));
        }
        let dg = g.vec_f32(b * c, -1.0, 1.0);
        if !bits_eq(&kernels::global_avgpool_bwd_p(&pool, &dg, b, hw, c),
                    &kernels::global_avgpool_bwd(&dg, b, hw, c)) {
            return Err(format!("global_avgpool_bwd {tag}"));
        }
        Ok(())
    });
}

/// End-to-end attention-path parity: `transformer_tiny`'s op graph (embed +
/// causal attention + MLP blocks) trained for a few FR steps at
/// `threads ∈ {1, 2, max}` must produce bit-identical loss trajectories
/// AND bit-identical parameters — the attention-path twin of
/// `thread_counts_train_bitwise_identically` in coordinator_integration.
/// The tiny config's shapes sit *above* `PAR_MIN_WORK`, so the multi-thread
/// runs really take the partitioned kernels.
#[test]
fn transformer_tiny_trains_bitwise_identically_across_thread_counts() {
    let m = NativeLmSpec::tiny(2).manifest().unwrap();
    let mut runs: Vec<(Vec<u32>, u64)> = Vec::new();
    for t in [1usize, 2, resolve_threads(0)] {
        let engine = Engine::native_with_threads(t);
        let mut tr = coordinator::fr::FrTrainer::new(
            ModuleStack::load(&engine, m.clone(), TrainConfig::default()).unwrap());
        let mut data = DataSource::for_manifest(&m, 5).unwrap();
        let mut losses = Vec::with_capacity(4);
        for _ in 0..4 {
            losses.push(tr.train_step(&data.train_batch(), 0.01).unwrap().loss.to_bits());
        }
        // FNV over every parameter bit of every module, in manifest order
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for module in &tr.stack_ref().modules {
            for p in module.params.iter() {
                for &v in p.f32s() {
                    h ^= v.to_bits() as u64;
                    h = h.wrapping_mul(0x100_0000_01b3);
                }
            }
        }
        runs.push((losses, h));
    }
    let (ref_losses, ref_hash) = runs[0].clone();
    for (i, (losses, hash)) in runs.iter().enumerate().skip(1) {
        assert_eq!(&ref_losses, losses, "loss trajectory diverged (run {i})");
        assert_eq!(ref_hash, *hash, "parameter hash diverged (run {i})");
    }
}

/// Aux-head path parity: the local-loss strategies' per-module auxiliary
/// heads (GAP + Dense forward, softmax-xent backward, and the local
/// optimizer step) must be bitwise identical across thread counts
/// {1, 2, max} — same loss trajectory, same trunk *and* aux parameter
/// bits. A conv model is used so the heads exercise the pool-partitioned
/// `global_avgpool(_bwd)` kernels, not just the matmuls.
#[test]
fn local_loss_aux_heads_train_bitwise_identically_across_thread_counts() {
    use features_replay::checkpoint::params_hash;
    use features_replay::coordinator::Algo;
    use features_replay::experiment::{Experiment, ScheduleSpec};
    use features_replay::runtime::BackendKind;

    for algo in [Algo::Dgl, Algo::Backlink] {
        let mut runs: Vec<(Vec<u32>, u64)> = Vec::new();
        for t in [1usize, 2, resolve_threads(0)] {
            let mut session = Experiment::new("resnet_s")
                .k(2)
                .algo(algo)
                .backend(BackendKind::Native)
                .threads(t)
                .seed(5)
                .schedule(ScheduleSpec::Constant)
                .session()
                .unwrap();
            let mut losses = Vec::with_capacity(3);
            for _ in 0..3 {
                let b = session.data.train_batch();
                losses.push(session.trainer.train_step(&b, 0.01).unwrap()
                    .loss.to_bits());
            }
            let modules = session.trainer.snapshot_modules().unwrap();
            let hash = params_hash(modules.iter()
                .flat_map(|ms| ms.params.iter().chain(ms.aux_params.iter())));
            runs.push((losses, hash));
        }
        let (ref_losses, ref_hash) = runs[0].clone();
        for (i, (losses, hash)) in runs.iter().enumerate().skip(1) {
            assert_eq!(&ref_losses, losses,
                       "{}: loss trajectory diverged (run {i})", algo.name());
            assert_eq!(ref_hash, *hash,
                       "{}: trunk+aux parameter hash diverged (run {i})",
                       algo.name());
        }
    }
}

/// The parity-coverage table frlint's `op-exhaustive` rule audits: every
/// [`NativeOp`] variant maps to the property test that pins its kernels'
/// thread-count parity (or, for the graph-level ops, the end-to-end
/// bitwise-trajectory test whose model contains the op). The entries are
/// function *pointers*, so renaming a test without updating this table is
/// a compile error, and a new enum variant without a row fails the
/// assertion (and frlint) until it is genuinely covered.
#[test]
fn native_op_parity_coverage_is_exhaustive() {
    use features_replay::runtime::NativeOp;
    let coverage: &[(&str, fn())] = &[
        // dense forward/backward are the matmul/matmul_nt/matmul_tn family
        ("Dense", pool_matmul_family_bitwise_parity),
        // two square dense layers + skip: same matmul family
        ("ResidualPair", pool_matmul_family_bitwise_parity),
        // exercised end-to-end by the transformer_tiny op graph
        ("LayerNorm", transformer_tiny_trains_bitwise_identically_across_thread_counts),
        ("Embed", transformer_tiny_trains_bitwise_identically_across_thread_counts),
        // conv forward/backward are im2col + matmul + col2im
        ("Conv2d", pool_im2col_col2im_bitwise_parity),
        ("ConvResidualPair", pool_im2col_col2im_bitwise_parity),
        ("AvgPool2d", pool_pooling_kernels_bitwise_parity),
        ("GlobalAvgPool", pool_pooling_kernels_bitwise_parity),
        ("Attention", pool_attention_kernels_bitwise_parity),
    ];
    let covered: Vec<&str> = coverage.iter().map(|(v, _)| *v).collect();
    assert_eq!(
        covered,
        NativeOp::VARIANT_NAMES,
        "every NativeOp variant needs a parity-coverage row (in declaration order)"
    );
}

/// The kernel-variant twin of the table above, audited by the same frlint
/// rule: every entry of [`blocked::KERNEL_VARIANTS`] — naive references,
/// blocked rewrites, the SIMD-shaped unrolls, the `Fast`-tier reduction and
/// the fused conv — maps to the property test that pins its contract
/// (bitwise parity with the naive chain at `Exact`, determinism plus the
/// documented ULP bound for `Fast`). A new variant string without a row
/// here fails the assertion until it is genuinely covered.
#[test]
fn blocked_kernel_parity_coverage_is_exhaustive() {
    let coverage: &[(&str, fn())] = &[
        ("matmul_naive", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_blocked_scalar", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_blocked_simd", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_tn_naive", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_tn_blocked", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_nt_naive", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_nt_blocked", blocked_matmul_variants_bitwise_match_naive),
        ("matmul_nt_fast", matmul_nt_fast_is_thread_deterministic_and_ulp_bounded),
        ("conv2d_fused", conv2d_fused_bitwise_matches_unfused),
    ];
    let covered: Vec<&str> = coverage.iter().map(|(v, _)| *v).collect();
    assert_eq!(
        covered,
        blocked::KERNEL_VARIANTS,
        "every blocked-kernel variant needs a parity-coverage row (in declaration order)"
    );
}

#[test]
fn replay_buffer_push_and_stale_are_zero_copy() {
    check("replay_zero_copy", 100, |g| {
        let cap = g.usize_in(1, 6);
        let mut buf = ReplayBuffer::new(cap, &[4], DType::F32);
        for _ in 0..g.usize_in(0, 10) {
            buf.push(Tensor::zeros(&[4], DType::F32));
        }
        let t = Tensor::from_f32(vec![4], g.vec_f32(4, -1.0, 1.0)).unwrap();
        buf.push(t.clone());
        if buf.stale(0).shares_storage(&t) {
            Ok(())
        } else {
            Err("ring push/stale must be refcount bumps".to_string())
        }
    });
}

/// A small but structurally complete checkpoint for the tamper property:
/// two modules, params + momentum + a non-empty replay ring + one pending
/// delta, so tampering can land in every section of the wire format.
fn tamper_fixture() -> Checkpoint {
    Checkpoint {
        meta: Meta {
            config: "mlp_tiny".to_string(),
            k: 2,
            algo: "FR".to_string(),
            step: 7,
            seed: 3,
            schedule: "constant".to_string(),
        },
        data_rng: vec![0x1234_5678, 42, 7],
        modules: (0..2usize).map(|m| ModuleState {
            params: vec![
                Tensor::from_f32(vec![2, 3],
                    (0..6).map(|x| x as f32 * 0.5 - 1.0).collect()).unwrap(),
                Tensor::from_f32(vec![3], vec![0.1, -0.2, 0.3]).unwrap(),
            ],
            velocity: vec![vec![0.25; 6], vec![-0.5; 3]],
            history: RingState {
                slots: vec![
                    Tensor::from_f32(vec![4], vec![0.1, 0.2, 0.3, 0.4]).unwrap(),
                ],
                head: 0,
                pushes: 1,
            },
            pending_delta: if m == 0 {
                Some(Tensor::from_f32(vec![2], vec![1.5, -2.5]).unwrap())
            } else {
                None
            },
            train_steps: 7,
            // module 0 carries a local-loss aux head so tampering can land
            // in the v2 aux sections of the wire format too
            aux_params: if m == 0 {
                vec![Tensor::from_f32(vec![3, 2],
                    vec![0.5, -0.5, 1.0, -1.0, 0.25, -0.25]).unwrap()]
            } else {
                Vec::new()
            },
            aux_velocity: if m == 0 { vec![vec![0.125; 6]] } else { Vec::new() },
        }).collect(),
    }
}

/// Randomized tamper property over the checkpoint wire format: any
/// truncation or bit-flip of a valid image must surface as a typed
/// [`CheckpointError`] — never a panic in the decoder, never a silent
/// success handing corrupted parameters to a resume. (The existing point
/// tests in `checkpoint/` cover one truncation and one bit flip; this
/// sweeps the whole format — header, meta strings, tensor dims, payload.)
#[test]
fn tampered_checkpoints_fail_typed_never_panic() {
    let base = tamper_fixture().to_bytes();
    Checkpoint::from_bytes(&base).expect("untampered fixture must decode");
    check("ckpt_tamper", 300, |g| {
        let mut bytes = base.clone();
        if g.rng.below(2) == 0 {
            bytes.truncate(g.rng.below(bytes.len()));
        } else {
            for _ in 0..g.usize_in(1, 8) {
                let bit = g.rng.below(bytes.len() * 8);
                bytes[bit / 8] ^= 1u8 << (bit % 8);
            }
        }
        if bytes == base {
            return Ok(()); // an even number of flips can cancel out
        }
        // FNV-1a's per-byte update is a bijection in the running hash, so
        // every single-byte tamper is detected; multi-flip collisions are
        // ~2^-64 and the seeds are deterministic, so this never flakes.
        let decoded = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| Checkpoint::from_bytes(&bytes)));
        match decoded {
            Err(_) => Err(format!("decoder panicked ({} tampered bytes)",
                                  bytes.len())),
            Ok(Ok(_)) => Err("tampered checkpoint decoded silently".to_string()),
            Ok(Err(_typed)) => Ok(()),
        }
    });
}
