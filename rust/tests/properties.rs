//! Property-based tests (mini-proptest) over the coordinator's pure
//! invariants: replay buffers, optimizer algebra, JSON round-trips, the
//! pipeline simulator, and the memory model's monotonicity.

use features_replay::coordinator::history::ReplayBuffer;
use features_replay::coordinator::pipeline_sim::{
    bp_data_parallel_ms, bp_iteration_ms, decoupled_iteration_ms, CommModel,
    MeasuredCosts,
};
use features_replay::optim::SgdMomentum;
use features_replay::runtime::{DType, Tensor};
use features_replay::testing::check;
use features_replay::util::json::Json;

#[test]
fn replay_buffer_returns_exact_lag() {
    check("replay_lag", 200, |g| {
        let cap = g.usize_in(1, 8);
        let pushes = g.usize_in(0, 40);
        let mut buf = ReplayBuffer::new(cap, &[1], DType::F32);
        for i in 0..pushes {
            buf.push(Tensor::from_f32(vec![1], vec![i as f32 + 1.0]).unwrap());
        }
        let lag = g.usize_in(0, cap - 1);
        let got = buf.stale(lag).f32s()[0];
        let want = if pushes > lag { (pushes - lag) as f32 } else { 0.0 };
        if got == want {
            Ok(())
        } else {
            Err(format!("cap={cap} pushes={pushes} lag={lag}: got {got}, want {want}"))
        }
    });
}

#[test]
fn replay_buffer_warmup_consistent_with_reads() {
    check("replay_warmup", 200, |g| {
        let cap = g.usize_in(1, 6);
        let mut buf = ReplayBuffer::new(cap, &[1], DType::F32);
        for _ in 0..g.usize_in(0, 20) {
            buf.push(Tensor::from_f32(vec![1], vec![1.0]).unwrap());
        }
        for lag in 0..cap {
            let warmed = buf.warmed(lag);
            let nonzero = buf.stale(lag).f32s()[0] != 0.0;
            if warmed != nonzero {
                return Err(format!("cap={cap} lag={lag}: warmed={warmed} nonzero={nonzero}"));
            }
        }
        Ok(())
    });
}

#[test]
fn sgd_without_momentum_is_linear_in_lr() {
    check("sgd_linear", 100, |g| {
        let n = g.usize_in(1, 32);
        let w0 = g.vec_f32(n, -1.0, 1.0);
        let gr = g.vec_f32(n, -1.0, 1.0);
        let lr = g.f32_in(0.001, 0.5);

        let run = |mult: f32| -> Vec<f32> {
            let mut p = vec![Tensor::from_f32(vec![n], w0.clone()).unwrap()];
            let gt = vec![Tensor::from_f32(vec![n], gr.clone()).unwrap()];
            let mut opt = SgdMomentum::new(&p, 0.0, 0.0);
            opt.step(&mut p, &gt, lr * mult).unwrap();
            p[0].f32s().to_vec()
        };
        let w1 = run(1.0);
        let w2 = run(2.0);
        // (w0 - w2) == 2 * (w0 - w1)
        for i in 0..n {
            let d1 = w0[i] - w1[i];
            let d2 = w0[i] - w2[i];
            if (d2 - 2.0 * d1).abs() > 1e-5 {
                return Err(format!("i={i}: d1={d1} d2={d2}"));
            }
        }
        Ok(())
    });
}

#[test]
fn sgd_momentum_matches_reference_recurrence() {
    check("sgd_momentum_ref", 50, |g| {
        let steps = g.usize_in(1, 10);
        let mu = g.f32_in(0.0, 0.99);
        let wd = g.f32_in(0.0, 0.01);
        let lr = g.f32_in(0.001, 0.1);
        let g0 = g.f32_in(-1.0, 1.0);

        let mut p = vec![Tensor::from_f32(vec![1], vec![1.0]).unwrap()];
        let gt = vec![Tensor::from_f32(vec![1], vec![g0]).unwrap()];
        let mut opt = SgdMomentum::new(&p, mu, wd);

        // scalar reference recurrence
        let (mut w, mut v) = (1.0f32, 0.0f32);
        for _ in 0..steps {
            opt.step(&mut p, &gt, lr).unwrap();
            let grad = g0 + wd * w;
            v = mu * v + grad;
            w -= lr * v;
        }
        let got = p[0].f32s()[0];
        if (got - w).abs() < 1e-4 {
            Ok(())
        } else {
            Err(format!("got {got}, reference {w}"))
        }
    });
}

#[test]
fn json_roundtrips_generated_documents() {
    check("json_roundtrip", 150, |g| {
        fn gen_value(g: &mut features_replay::testing::Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.usize_in(0, 1) == 1),
                2 => Json::Num((g.f32_in(-1e6, 1e6) as f64 * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-\"quoted\"\n", g.usize_in(0, 999))),
                4 => Json::Arr((0..g.usize_in(0, 4))
                    .map(|_| gen_value(g, depth - 1))
                    .collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..g.usize_in(0, 4) {
                        m.insert(format!("k{i}"), gen_value(g, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = gen_value(g, 3);
        let text = v.to_string_pretty();
        match Json::parse(&text) {
            Ok(back) if back == v => Ok(()),
            Ok(back) => Err(format!("roundtrip mismatch:\n{v:?}\nvs\n{back:?}")),
            Err(e) => Err(format!("reparse failed: {e} on {text}")),
        }
    });
}

#[test]
fn decoupled_never_slower_than_locked_bp() {
    check("fr_le_bp", 200, |g| {
        let k = g.usize_in(1, 8);
        let costs = MeasuredCosts {
            fwd_ms: g.vec_f32(k, 0.0, 50.0).iter().map(|&x| x as f64).collect(),
            bwd_ms: g.vec_f32(k, 0.0, 50.0).iter().map(|&x| x as f64).collect(),
            aux_ms: vec![0.0; k],
            boundary_bytes: g.vec_usize(k.saturating_sub(1), 0, 1_000_000),
            param_bytes: 0,
        };
        let comm = CommModel::default();
        let bp = bp_iteration_ms(&costs, &comm);
        let fr = decoupled_iteration_ms(&costs, &comm);
        // FR replaces sum(bwd) + down-transfers with max(bwd): never slower
        if fr <= bp + 1e-9 {
            Ok(())
        } else {
            Err(format!("fr {fr} > bp {bp} at k={k}"))
        }
    });
}

#[test]
fn data_parallel_monotone_compute_term() {
    check("dp_compute", 100, |g| {
        let k = g.usize_in(1, 6);
        let costs = MeasuredCosts {
            fwd_ms: g.vec_f32(k, 1.0, 20.0).iter().map(|&x| x as f64).collect(),
            bwd_ms: g.vec_f32(k, 1.0, 20.0).iter().map(|&x| x as f64).collect(),
            aux_ms: vec![0.0; k],
            boundary_bytes: vec![0; k.saturating_sub(1)],
            param_bytes: 0, // no allreduce -> pure compute scaling
        };
        let comm = CommModel { latency_ms: 0.0, bytes_per_ms: 1e30 };
        let mut prev = f64::INFINITY;
        for n in 1..=4 {
            let t = bp_data_parallel_ms(&costs, &comm, n);
            if t > prev + 1e-9 {
                return Err(format!("dp time increased at n={n}"));
            }
            prev = t;
        }
        Ok(())
    });
}

#[test]
fn tensor_clone_shares_until_write_then_detaches() {
    check("tensor_cow", 100, |g| {
        let rank = g.usize_in(1, 4);
        let shape = g.vec_usize(rank, 1, 8);
        let n: usize = shape.iter().product();
        let data = g.vec_f32(n, -100.0, 100.0);
        let t = Tensor::from_f32(shape.clone(), data.clone()).unwrap();
        let mut c = t.clone();
        if !c.shares_storage(&t) {
            return Err("clone must share storage".to_string());
        }
        let i = g.usize_in(0, n - 1);
        c.f32s_mut()[i] += 1.0;
        if c.shares_storage(&t) {
            return Err("write must detach the clone".to_string());
        }
        if t.f32s() != &data[..] {
            return Err("original mutated through a clone".to_string());
        }
        Ok(())
    });
}

#[test]
fn replay_buffer_push_and_stale_are_zero_copy() {
    check("replay_zero_copy", 100, |g| {
        let cap = g.usize_in(1, 6);
        let mut buf = ReplayBuffer::new(cap, &[4], DType::F32);
        for _ in 0..g.usize_in(0, 10) {
            buf.push(Tensor::zeros(&[4], DType::F32));
        }
        let t = Tensor::from_f32(vec![4], g.vec_f32(4, -1.0, 1.0)).unwrap();
        buf.push(t.clone());
        if buf.stale(0).shares_storage(&t) {
            Ok(())
        } else {
            Err("ring push/stale must be refcount bumps".to_string())
        }
    });
}
