//! Crash-safety matrix: deterministic fault injection × checkpoint/resume.
//!
//! Gated behind the `fault-inject` cargo feature (see `[[test]]` in
//! Cargo.toml): run with `cargo test --features fault-inject --test faults`.
//!
//! The keystone contract under test: a threaded FR run killed mid-flight —
//! any worker, any phase (forward / backward / optimizer write-back), by
//! panic or error — and resumed from its latest checkpoint must produce a
//! loss trajectory and final parameter hash **bit-identical** to a run
//! that never crashed, at every thread count. A worker that *stalls*
//! instead of dying must surface as a bounded, attributed diagnosis rather
//! than hanging the leader.

use features_replay::checkpoint;
use features_replay::experiment::{Experiment, ParallelSession, ScheduleSpec};
use features_replay::testing::faults::FaultPlan;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fr-faults-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

const STEPS: usize = 6;
const FP: &str = "const(0.01)"; // ScheduleSpec::Constant at the default lr

fn base_exp(threads: usize) -> Experiment {
    Experiment::new("transformer_tiny").k(2).steps(STEPS).seed(5)
        .threads(threads)
        .schedule(ScheduleSpec::Constant)
        .eval_every(100).eval_batches(1)
        .checkpoint_every(2)
}

/// Drive the fleet exactly like `frctl parallel` does: step, schedule,
/// checkpoint cadence. Returns the loss bits of every step it completed.
fn drive(ps: &mut ParallelSession, steps: usize) -> anyhow::Result<Vec<u32>> {
    let from = ps.par.step();
    let mut losses = Vec::new();
    for step in from..steps {
        let b = ps.data.train_batch();
        let lr = ps.lr_at(step);
        let s = ps.par.train_step(&b, lr)?;
        losses.push(s.loss.to_bits());
        if ps.should_checkpoint(step + 1) {
            ps.write_checkpoint()?;
        }
    }
    Ok(losses)
}

fn fleet_params_hash(ps: &mut ParallelSession) -> u64 {
    let ckpt = ps.par.snapshot(&ps.data, FP).unwrap();
    checkpoint::params_hash(ckpt.modules.iter().flat_map(|m| m.params.iter()))
}

/// Reference run: no faults, no checkpoint dir (pure channel path).
fn uninterrupted(threads: usize) -> (Vec<u32>, u64) {
    let mut ps = base_exp(threads).spawn_parallel().unwrap();
    let losses = drive(&mut ps, STEPS).unwrap();
    let hash = fleet_params_hash(&mut ps);
    ps.par.shutdown().unwrap();
    (losses, hash)
}

/// Crash a checkpointing run with `fault`, then resume from the latest
/// checkpoint and finish. Returns (step resumed from, resumed-leg loss
/// bits, final params hash, rendered crash error).
fn crash_and_resume(threads: usize, fault: &str) -> (usize, Vec<u32>, u64, String) {
    let dir = tmpdir(&format!("t{threads}-{}", fault.replace(':', "-")));
    let plan = FaultPlan::parse(fault).unwrap();

    let mut ps = base_exp(threads).checkpoint_dir(&dir).fault(plan)
        .spawn_parallel().unwrap();
    let err = match drive(&mut ps, STEPS) {
        Ok(_) => panic!("fault {fault} never fired"),
        Err(e) => format!("{e:#}"),
    };
    drop(ps); // crashed fleet: Drop must tear down without hanging

    let mut ps2 = base_exp(threads).checkpoint_dir(&dir).resume_from(&dir)
        .spawn_parallel().unwrap();
    let resumed_from = ps2.par.step();
    let tail = drive(&mut ps2, STEPS).unwrap();
    let hash = fleet_params_hash(&mut ps2);
    ps2.par.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).ok();
    (resumed_from, tail, hash, err)
}

fn assert_resume_matches(fault: &str, expect_from: usize,
                         base: &(Vec<u32>, u64),
                         got: &(usize, Vec<u32>, u64, String)) {
    let (base_losses, base_hash) = base;
    let (from, tail, hash, err) = got;
    assert!(err.contains("injected fault"),
            "{fault}: crash error lost the root cause: {err}");
    assert_eq!(*from, expect_from, "{fault}: resumed from the wrong step");
    assert_eq!(&base_losses[*from..], &tail[..],
               "{fault}: resumed loss trajectory diverged");
    assert_eq!(base_hash, hash, "{fault}: resumed params hash diverged");
}

/// The full crash matrix at one thread count: every phase × first and last
/// module × both failure kinds, including a crash *not* aligned with the
/// checkpoint cadence (resumes from an earlier step and replays more).
#[test]
fn crash_resume_matrix_is_bit_identical() {
    let base = uninterrupted(2);
    // checkpoints land at steps 2 and 4; faults at worker-step 4 resume
    // from 4, the step-3 fault resumes from 2 and replays two steps.
    for (fault, expect_from) in [
        ("0:4:fwd:panic", 4),
        ("1:4:fwd:error", 4),
        ("0:4:bwd:error", 4),
        ("1:4:bwd:panic", 4),
        ("0:4:optwb:panic", 4),
        ("1:4:optwb:error", 4),
        ("1:3:bwd:panic", 2),
    ] {
        let got = crash_and_resume(2, fault);
        assert_resume_matches(fault, expect_from, &base, &got);
    }
}

/// The keystone at every thread count: 1 (exact single-thread reference),
/// 2, and 0 = auto (all available parallelism, split across workers) — and
/// the final weights agree bitwise *across* thread counts too (PR 5's
/// kernel-determinism contract extended through crash/resume).
#[test]
fn crash_resume_is_bit_identical_at_every_thread_count() {
    let mut hashes = Vec::new();
    for threads in [1usize, 2, 0] {
        let base = uninterrupted(threads);
        let got = crash_and_resume(threads, "1:4:bwd:panic");
        assert_resume_matches("1:4:bwd:panic", 4, &base, &got);
        hashes.push(base.1);
    }
    assert!(hashes.windows(2).all(|w| w[0] == w[1]),
            "final params differ across thread counts: {hashes:?}");
}

/// A silent worker (stall, not death) must become a *bounded* fleet
/// failure naming the phase and the unresponsive worker — not an
/// indefinite leader hang — and leave the fleet cleanly unusable.
#[test]
fn stalled_worker_surfaces_bounded_attributed_failure() {
    let plan = FaultPlan::parse("0:2:bwd:stall:5000").unwrap();
    let mut ps = Experiment::new("mlp_tiny").k(2).steps(STEPS).seed(1)
        .schedule(ScheduleSpec::Constant).eval_every(100).eval_batches(1)
        .recv_timeout_ms(150).fault(plan)
        .spawn_parallel().unwrap();
    let t0 = std::time::Instant::now();
    let err = drive(&mut ps, STEPS).unwrap_err();
    let waited = t0.elapsed();
    let msg = format!("{err:#}");
    assert!(msg.contains("stalled"), "want a stall diagnosis, got: {msg}");
    assert!(msg.contains("train step"), "stall should name the phase: {msg}");
    assert!(msg.contains("worker 0"), "stall should name the worker: {msg}");
    // two 150 ms windows + step time, never the 5 s stall
    assert!(waited < std::time::Duration::from_secs(4),
            "leader waited {waited:?} — recv_timeout not honored");
    // the fleet is detached: later calls fail fast instead of hanging
    let b = ps.data.train_batch();
    let err2 = ps.par.train_step(&b, 0.01).unwrap_err();
    assert!(format!("{err2:#}").contains("shut down"), "{err2:#}");
    drop(ps); // detached workers: Drop is a no-op, must not hang
}
