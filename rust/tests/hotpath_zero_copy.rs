//! The acceptance check for the zero-copy hot path, as a test: after the
//! pipeline warms up, FR training steps must perform
//!
//! - zero deep buffer copies (replay pushes, stale reads, delta hand-offs
//!   are Arc refcount bumps; copy-on-write never fires), and
//! - zero parameter re-marshals (params are resident in the backend; the
//!   native executor reads host buffers in place).
//!
//! This lives in its own integration-test binary ON PURPOSE: the copy
//! counters are process-global, and a dedicated process with a single test
//! keeps them race-free.

use features_replay::coordinator::{self, ModuleStack, TrainConfig, Trainer};
use features_replay::data::DataSource;
use features_replay::runtime::{copy_metrics, Engine, NativeMlpSpec};

#[test]
fn fr_steady_state_performs_no_deep_copies_or_remarshals() {
    let m = NativeMlpSpec::tiny(4).manifest().unwrap();
    let engine = Engine::native();
    let stack = ModuleStack::load(&engine, m.clone(), TrainConfig::default()).unwrap();
    let mut fr = coordinator::fr::FrTrainer::new(stack);
    let mut data = DataSource::for_manifest(&m, 21).unwrap();

    // warm the pipeline past the zero-prefill phase
    for _ in 0..m.k {
        let b = data.train_batch();
        fr.train_step(&b, 0.01).unwrap();
    }

    copy_metrics::reset();
    for _ in 0..4 {
        let b = data.train_batch();
        let stats = fr.train_step(&b, 0.01).unwrap();
        assert!(stats.loss.is_finite());
    }
    assert_eq!(copy_metrics::deep_copies(), 0,
               "FR steady state must not deep-copy any tensor buffer");
    assert_eq!(copy_metrics::deep_copy_bytes(), 0);
    assert_eq!(copy_metrics::param_remarshals(), 0,
               "resident params must not be re-marshaled per step");
    assert!(copy_metrics::shallow_clones() > 0,
            "the hot path runs on Arc clones");
}
