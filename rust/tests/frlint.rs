//! frlint self-check: the shipped tree must lint clean, with every
//! suppression justified. This is the same scan `scripts/ci.sh` runs via
//! `cargo run --bin frlint`, wired into `cargo test` so a violation also
//! fails the plain tier-1 suite (and shows the full report).

use std::path::Path;

use features_replay::lint;

#[test]
fn repo_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint::run_repo(root).expect("scanning the source tree");
    // A scan that saw almost nothing would pass vacuously; the crate has
    // dozens of sources, so a tiny count means the walker broke.
    assert!(
        report.files_scanned > 30,
        "suspiciously small scan set: {} files",
        report.files_scanned
    );
    assert!(report.clean(), "frlint violations:\n{}", report.render());
    assert!(
        report.warnings.is_empty(),
        "stale suppressions must be removed:\n{}",
        report.render()
    );
    // The tree carries deliberate, documented infinite waits (the fleet
    // workers' command channels) — if the suppression set is empty, the
    // directives were lost, not fixed.
    assert!(
        !report.suppressed.is_empty(),
        "expected justified suppressions in the tree"
    );
    for sup in &report.suppressed {
        assert!(
            !sup.reason.trim().is_empty(),
            "empty suppression reason at {}:{}",
            sup.finding.file,
            sup.finding.line
        );
    }
}

#[test]
fn wire_fingerprint_helper_matches_the_declared_constant() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let computed = lint::computed_wire_fingerprint(root)
        .expect("reading checkpoint/mod.rs")
        .expect("codec anchors present");
    assert_eq!(computed.0, features_replay::checkpoint::VERSION);
    assert_eq!(
        computed.1,
        features_replay::checkpoint::WIRE_FINGERPRINT,
        "declared WIRE_FINGERPRINT is stale (computed {:#018x})",
        computed.1
    );
}
