//! Integration tests of the training strategies' semantic contracts.
//!
//! These run on the native CPU backend with a procedural tiny-MLP manifest,
//! so they exercise the full stack offline — no `make artifacts` needed.
//! (The seed repo's versions self-skipped without artifacts; the native
//! backend is what makes them actually run.)

use features_replay::checkpoint::{self, Checkpoint, CheckpointError, Meta};
use features_replay::coordinator::{
    self, make_trainer, Algo, ModuleStack, TrainConfig, Trainer,
};
use features_replay::data::{Batch, DataSource};
use features_replay::experiment::{Experiment, ScheduleSpec};
use features_replay::optim::ConstantLr;
use features_replay::runtime::{BackendKind, Engine, Manifest, NativeMlpSpec, Tensor};

fn manifest_k(k: usize) -> Manifest {
    NativeMlpSpec::tiny(k).manifest().unwrap()
}

/// Fresh scratch dir under the OS temp root (no tempfile crate offline).
fn tmpdir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fr-itest-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn stack_params_hash(stack: &ModuleStack) -> u64 {
    checkpoint::params_hash(stack.modules.iter().flat_map(|mm| mm.params.iter()))
}

fn load_stack(m: &Manifest, engine: &Engine) -> ModuleStack {
    ModuleStack::load(engine, m.clone(), TrainConfig::default()).unwrap()
}

fn batch_for(manifest: &Manifest, seed: u64) -> Batch {
    let mut data = DataSource::for_manifest(manifest, seed).unwrap();
    data.train_batch()
}

/// FR's *last* module uses the current input and true loss gradient, so its
/// first-step gradient must equal BP's for that module exactly.
#[test]
fn fr_last_module_matches_bp_on_first_step() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let stack = load_stack(&m, &engine);
    let batch = batch_for(&m, 1);

    let (_, bp_grads, _) = stack.bp_grads(&batch).unwrap();

    let mut fr = coordinator::fr::FrTrainer::new(load_stack(&m, &engine));
    let mut fr_grads: Vec<Vec<Tensor>> = Vec::new();
    fr.step_capture(&batch, 0.0, Some(&mut fr_grads)).unwrap();

    let k_last = bp_grads.len() - 1;
    for (a, b) in bp_grads[k_last].iter().zip(&fr_grads[k_last]) {
        let diff: f32 = a.f32s().iter().zip(b.f32s())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-4, "last-module grads differ by {diff}");
    }
}

/// With K=1 there is no decoupling at all: FR, DDG and BP must produce the
/// same parameters after several steps.
#[test]
fn all_methods_equal_bp_at_k1() {
    let m = manifest_k(1);
    let engine = Engine::native();
    let mut data = DataSource::for_manifest(&m, 3).unwrap();
    let batches: Vec<Batch> = (0..3).map(|_| data.train_batch()).collect();

    let mut finals: Vec<Vec<f32>> = Vec::new();
    for algo in [Algo::Bp, Algo::Fr, Algo::Ddg] {
        let mut t = make_trainer(&engine, &m, algo, TrainConfig::default()).unwrap();
        for b in &batches {
            t.train_step(b, 0.01).unwrap();
        }
        finals.push(t.stack().modules[0].params[0].f32s().to_vec());
    }
    for other in &finals[1..] {
        let diff: f32 = finals[0].iter().zip(other)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-4, "K=1 methods disagree by {diff}");
    }
}

/// After enough identical-lag steps, FR gradients should align with BP
/// (sigma -> positive); weak check: the probe returns finite sane values.
#[test]
fn sigma_probe_produces_sane_values() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let stack = load_stack(&m, &engine);
    let mut fr = coordinator::fr::FrTrainer::new(stack);
    let mut data = DataSource::for_manifest(&m, 5).unwrap();

    let mut last = None;
    for step in 0..6 {
        let batch = data.train_batch();
        let (sample, loss) =
            coordinator::sigma::probe_step(&mut fr, &batch, 0.005, step).unwrap();
        assert!(loss.is_finite());
        assert_eq!(sample.per_module.len(), 4);
        assert!(sample.per_module.iter().all(|s| s.is_finite()));
        last = Some(sample);
    }
    // the last module's direction is exact BP -> sigma == 1
    let s = last.unwrap();
    assert!((s.per_module[3] - 1.0).abs() < 1e-3,
            "last module sigma {} should be 1", s.per_module[3]);
    // after the pipeline warms up, lower-module sigma should be positive
    assert!(s.per_module[0] > -0.5, "sigma way off: {:?}", s.per_module);
}

/// Training must reduce the loss for every method on the tiny MLP —
/// the whole zoo, local-loss strategies (DGL, BackLink) included.
#[test]
fn short_training_reduces_loss_all_methods() {
    let m = manifest_k(4);
    let engine = Engine::native();

    for algo in Algo::ALL {
        let mut t = make_trainer(&engine, &m, algo, TrainConfig::default()).unwrap();
        let mut data = DataSource::for_manifest(&m, 7).unwrap();
        let mut first = None;
        let mut last = 0.0f32;
        for step in 0..40 {
            let b = data.train_batch();
            let s = t.train_step(&b, 0.004).unwrap();
            if step == 0 {
                first = Some(s.loss);
            }
            last = s.loss;
        }
        let first = first.unwrap();
        assert!(last.is_finite(), "{}: diverged", t.name());
        assert!(last < first,
                "{}: loss did not decrease ({first} -> {last})", t.name());
    }
}

/// The threaded K-worker FR must produce the same training trajectory as the
/// single-timeline FrTrainer (same losses step by step), and its aggregated
/// history accounting must match the sequential trainer's memory report.
#[test]
fn parallel_fr_matches_sequential_fr() {
    let m = manifest_k(4);
    let engine = Engine::native();

    let mut seq = coordinator::fr::FrTrainer::new(load_stack(&m, &engine));
    let mut par = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();

    let mut data1 = DataSource::for_manifest(&m, 11).unwrap();
    let mut data2 = DataSource::for_manifest(&m, 11).unwrap();

    for step in 0..8 {
        let b1 = data1.train_batch();
        let b2 = data2.train_batch();
        let s1 = seq.train_step(&b1, 0.01).unwrap();
        let s2 = par.train_step(&b2, 0.01).unwrap();
        assert!((s1.loss - s2.loss).abs() < 1e-4,
                "step {step}: sequential {} vs parallel {}", s1.loss, s2.loss);
        // the fleet's aggregated replay-ring bytes = the sequential trainer's
        assert_eq!(s1.history_bytes, s2.history_bytes, "step {step}");
    }
    assert_eq!(seq.memory().history,
               par.train_step(&data2.train_batch(), 0.0).unwrap().history_bytes);

    // eval parity too
    let eb = data1.test_batch(0);
    let (l2, e2) = par.eval_batch(&eb).unwrap();
    let hs = seq.stack_ref().forward_chain(&eb.input).unwrap();
    let (l1, a1) = features_replay::metrics::xent_and_acc(hs.last().unwrap(), &eb.labels);
    assert!((l1 - l2).abs() < 1e-6);
    assert!((e2 - (1.0 - a1)).abs() < 1e-9);

    par.shutdown().unwrap();
}

/// A worker whose step fails must surface the root cause through
/// `train_step` — not a bare "worker died mid-step" — and leave the fleet
/// cleanly torn down (later calls fail fast instead of hanging).
#[test]
fn parallel_fr_worker_error_surfaces_root_cause() {
    let m = manifest_k(2);
    let mut par = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();
    let mut data = DataSource::for_manifest(&m, 7).unwrap();
    // one good step so every worker is past its iteration-0 paths
    let good = data.train_batch();
    par.train_step(&good, 0.01).unwrap();
    // corrupt the labels: the last worker's fused loss head rejects them
    let mut bad = data.train_batch();
    bad.labels = Tensor::from_i32(vec![3], vec![0, 1, 2]).unwrap();
    let err = par.train_step(&bad, 0.01).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("labels"),
            "error should carry the worker's root cause, got: {msg}");
    // the fleet is down; subsequent steps error cleanly
    let next = data.train_batch();
    let err2 = par.train_step(&next, 0.01).unwrap_err();
    assert!(format!("{err2:#}").contains("shut down"), "{err2:#}");
    par.shutdown().unwrap();
}

/// threads=1 (the exact old single-thread path) and a multi-thread pool
/// must produce bitwise-identical training trajectories: the pool only
/// partitions output rows, it never reorders a float accumulation.
#[test]
fn thread_counts_train_bitwise_identically() {
    let m = manifest_k(2);
    let e1 = Engine::native_with_threads(1);
    let e4 = Engine::native_with_threads(4);
    let mut t1 = coordinator::fr::FrTrainer::new(
        ModuleStack::load(&e1, m.clone(), TrainConfig::default()).unwrap());
    let mut t4 = coordinator::fr::FrTrainer::new(
        ModuleStack::load(&e4, m.clone(), TrainConfig::default()).unwrap());
    let mut d1 = DataSource::for_manifest(&m, 5).unwrap();
    let mut d4 = DataSource::for_manifest(&m, 5).unwrap();
    for step in 0..6 {
        let s1 = t1.train_step(&d1.train_batch(), 0.01).unwrap();
        let s4 = t4.train_step(&d4.train_batch(), 0.01).unwrap();
        assert_eq!(s1.loss.to_bits(), s4.loss.to_bits(),
                   "step {step}: {} vs {}", s1.loss, s4.loss);
    }
}

/// Memory reports: FR holds history+deltas; BP holds only activations; the
/// live DDG stash grows until the pipeline fills.
#[test]
fn memory_reports_reflect_method_structure() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let mut data = DataSource::for_manifest(&m, 1).unwrap();

    let mut bp = make_trainer(&engine, &m, Algo::Bp, TrainConfig::default()).unwrap();
    let mut fr = make_trainer(&engine, &m, Algo::Fr, TrainConfig::default()).unwrap();
    let mut ddg = make_trainer(&engine, &m, Algo::Ddg, TrainConfig::default()).unwrap();
    for _ in 0..5 {
        let b = data.train_batch();
        bp.train_step(&b, 0.01).unwrap();
        fr.train_step(&b, 0.01).unwrap();
        ddg.train_step(&b, 0.01).unwrap();
    }
    let (mb, mf, md) = (bp.memory(), fr.memory(), ddg.memory());
    assert_eq!(mb.history, 0);
    assert!(mf.history > 0 && mf.deltas > 0);
    assert!(md.history > 0 && md.weight_copies > 0);
    assert!(md.total() > mb.total());
}

/// run_training end-to-end: curve recorded, timings collected, no divergence.
#[test]
fn run_training_records_curves() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let mut t = make_trainer(&engine, &m, Algo::Fr, TrainConfig::default()).unwrap();
    let mut data = DataSource::for_manifest(&m, 2).unwrap();
    let opts = coordinator::RunOptions {
        steps: 12, eval_every: 4, eval_batches: 2, steps_per_epoch: 4,
        ..Default::default()
    };
    let res = coordinator::run_training(
        t.as_mut(), &mut data, &ConstantLr(0.01), &opts).unwrap();
    assert!(!res.diverged);
    assert!(res.curve.points.len() >= 3);
    assert_eq!(res.timings.len(), 12);
    assert!(res.curve.points.iter().all(|p| p.sim_ms > 0.0));
    assert!(res.final_memory.total() > 0);
}

/// Keystone contract, sequential loop: a run checkpointed mid-way and
/// resumed in a fresh process-equivalent (new trainer, new data source)
/// must end bit-identical to a run that never stopped — same final
/// parameter hash, same final recorded loss.
#[test]
fn sequential_checkpoint_resume_is_bit_identical() {
    let dir = tmpdir("seq-resume");
    let exp = |steps: usize| {
        Experiment::new("mlp_tiny").k(4).steps(steps).seed(3)
            .schedule(ScheduleSpec::Constant).eval_every(4).eval_batches(1)
    };

    // uninterrupted reference
    let mut a = exp(10).session().unwrap();
    let ra = a.run().unwrap();
    let hash_a = stack_params_hash(a.trainer.stack());

    // interrupted run: leg 1 stops after 6 steps, checkpointing at 3 and 6
    let mut b1 = exp(6).checkpoint_dir(&dir).checkpoint_every(3)
        .session().unwrap();
    b1.run().unwrap();
    assert!(checkpoint::checkpoint_path(&dir, 6).is_file());
    // leg 2: fresh everything, resume from the directory's latest checkpoint
    let mut b2 = exp(10).resume_from(&dir).session().unwrap();
    let rb = b2.run().unwrap();
    let hash_b = stack_params_hash(b2.trainer.stack());

    assert_eq!(hash_a, hash_b, "resumed params differ from uninterrupted run");
    let last_a = ra.curve.points.last().unwrap().train_loss;
    let last_b = rb.curve.points.last().unwrap().train_loss;
    assert_eq!(last_a.to_bits(), last_b.to_bits(),
               "final loss {last_a} vs resumed {last_b}");
    std::fs::remove_dir_all(&dir).ok();
}

/// The same keystone contract for the local-loss strategies, whose
/// checkpoints additionally carry auxiliary-head parameters and optimizer
/// velocity: interrupt + resume must be bit-identical to a straight run,
/// with the hash taken over trunk *and* aux parameters so a dropped or
/// stale aux head cannot hide.
#[test]
fn local_loss_checkpoint_resume_is_bit_identical() {
    let aux_aware_hash = |t: &dyn Trainer| {
        let modules = t.snapshot_modules().unwrap();
        checkpoint::params_hash(modules.iter()
            .flat_map(|ms| ms.params.iter().chain(ms.aux_params.iter())))
    };

    for algo in [Algo::Dgl, Algo::Backlink] {
        let dir = tmpdir(&format!("seq-resume-{}", algo.cli_name()));
        let exp = |steps: usize| {
            Experiment::new("mlp_tiny").k(4).algo(algo).steps(steps).seed(3)
                .schedule(ScheduleSpec::Constant).eval_every(4).eval_batches(1)
        };

        // uninterrupted reference
        let mut a = exp(10).session().unwrap();
        let ra = a.run().unwrap();
        let hash_a = aux_aware_hash(a.trainer.as_ref());

        // interrupted run: leg 1 stops after 6 steps, checkpointing at 3, 6
        let mut b1 = exp(6).checkpoint_dir(&dir).checkpoint_every(3)
            .session().unwrap();
        b1.run().unwrap();
        let ckpt = Checkpoint::read(
            &checkpoint::checkpoint_path(&dir, 6)).unwrap();
        let k = ckpt.modules.len();
        assert!(ckpt.modules[..k - 1].iter().all(|ms| !ms.aux_params.is_empty()
                    && ms.aux_velocity.len() == ms.aux_params.len()),
                "{}: every non-last module must checkpoint its aux head",
                algo.name());
        assert!(ckpt.modules[k - 1].aux_params.is_empty(),
                "{}: the last module has the real loss head, no aux state",
                algo.name());

        // leg 2: fresh everything, resume from the latest checkpoint
        let mut b2 = exp(10).resume_from(&dir).session().unwrap();
        let rb = b2.run().unwrap();
        assert_eq!(hash_a, aux_aware_hash(b2.trainer.as_ref()),
                   "{}: resumed trunk+aux params differ from uninterrupted run",
                   algo.name());
        let last_a = ra.curve.points.last().unwrap().train_loss;
        let last_b = rb.curve.points.last().unwrap().train_loss;
        assert_eq!(last_a.to_bits(), last_b.to_bits(),
                   "{}: final loss {last_a} vs resumed {last_b}", algo.name());
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Keystone contract, threaded fleet: snapshot a live fleet to disk, tear
/// it down, rebuild from the file with `ParallelFr::resume`, and the
/// continued per-step losses + final parameter hash are bit-identical to a
/// fleet that ran straight through (which itself also snapshots mid-run,
/// covering the delta-prefetch path on a surviving fleet).
#[test]
fn parallel_snapshot_resume_is_bit_identical() {
    let m = manifest_k(4);
    let dir = tmpdir("par-resume");
    let fp = "const(0.01)";

    // uninterrupted reference fleet (with a mid-run snapshot it ignores)
    let mut par_a = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();
    let mut data_a = DataSource::for_manifest(&m, 11).unwrap();
    let mut losses_a = Vec::new();
    for step in 0..8 {
        losses_a.push(par_a.train_step(&data_a.train_batch(), 0.01).unwrap()
            .loss.to_bits());
        if step == 3 {
            par_a.snapshot(&data_a, fp).unwrap();
        }
    }
    let hash_a = checkpoint::params_hash(
        par_a.snapshot(&data_a, fp).unwrap().modules.iter()
            .flat_map(|ms| ms.params.iter()));
    par_a.shutdown().unwrap();

    // crashing fleet: 4 steps, snapshot to disk, torn down
    let mut par_b = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();
    let mut data_b = DataSource::for_manifest(&m, 11).unwrap();
    let mut losses_b = Vec::new();
    for _ in 0..4 {
        losses_b.push(par_b.train_step(&data_b.train_batch(), 0.01).unwrap()
            .loss.to_bits());
    }
    let ckpt = par_b.snapshot(&data_b, fp).unwrap();
    assert_eq!(ckpt.meta.step, 4);
    let path = checkpoint::checkpoint_path(&dir, ckpt.meta.step);
    ckpt.write_atomic(&path).unwrap();
    par_b.shutdown().unwrap();

    // resume in a fresh fleet + fresh data source
    let ckpt = Checkpoint::read(&path).unwrap();
    ckpt.validate_matches(&m.config, m.k, "FR", fp).unwrap();
    let mut par_c = coordinator::parallel::ParallelFr::resume(
        m.clone(), TrainConfig::default(), BackendKind::Native, &ckpt).unwrap();
    assert_eq!(par_c.step(), 4);
    let mut data_c = DataSource::for_manifest(&m, 11).unwrap();
    data_c.restore_rng_state(&ckpt.data_rng).unwrap();
    for _ in 4..8 {
        losses_b.push(par_c.train_step(&data_c.train_batch(), 0.01).unwrap()
            .loss.to_bits());
    }
    let hash_c = checkpoint::params_hash(
        par_c.snapshot(&data_c, fp).unwrap().modules.iter()
            .flat_map(|ms| ms.params.iter()));
    par_c.shutdown().unwrap();

    assert_eq!(losses_a, losses_b, "resumed trajectory diverged");
    assert_eq!(hash_a, hash_c, "resumed params differ from uninterrupted run");
    std::fs::remove_dir_all(&dir).ok();
}

/// Damaged checkpoint files must come back as *typed* errors — truncation,
/// bitflips, foreign files, and future format versions each get their own
/// variant (no silent half-resume, no stringly matching).
#[test]
fn corrupted_checkpoints_are_rejected_with_typed_errors() {
    let dir = tmpdir("ckpt-corrupt");
    let m = manifest_k(2);
    let engine = Engine::native();
    let mut fr = coordinator::fr::FrTrainer::new(load_stack(&m, &engine));
    let mut data = DataSource::for_manifest(&m, 9).unwrap();
    for _ in 0..3 {
        fr.train_step(&data.train_batch(), 0.01).unwrap();
    }
    let ckpt = Checkpoint {
        meta: Meta {
            config: m.config.clone(), k: m.k, algo: "FR".into(), step: 3,
            seed: 9, schedule: "const(0.01)".into(),
        },
        data_rng: data.rng_state(),
        modules: fr.snapshot_modules().unwrap(),
    };
    let path = checkpoint::checkpoint_path(&dir, 3);
    ckpt.write_atomic(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // a torn copy (atomic rename never produces one, but a backup tool can)
    let trunc = dir.join("trunc.fckpt");
    std::fs::write(&trunc, &bytes[..bytes.len() - 7]).unwrap();
    match Checkpoint::read(&trunc) {
        Err(CheckpointError::Truncated { .. }) => {}
        other => panic!("truncated file: want Truncated, got {other:?}"),
    }

    // one flipped payload bit
    let mut flipped = bytes.clone();
    let n = flipped.len();
    flipped[n - 1] ^= 0x40;
    let flip = dir.join("flip.fckpt");
    std::fs::write(&flip, &flipped).unwrap();
    match Checkpoint::read(&flip) {
        Err(CheckpointError::ChecksumMismatch { .. }) => {}
        other => panic!("bitflip: want ChecksumMismatch, got {other:?}"),
    }

    // not a checkpoint at all
    let mut alien = bytes.clone();
    alien[..8].copy_from_slice(b"NOTCKPT\0");
    let alien_path = dir.join("alien.fckpt");
    std::fs::write(&alien_path, &alien).unwrap();
    match Checkpoint::read(&alien_path) {
        Err(CheckpointError::BadMagic { .. }) => {}
        other => panic!("foreign file: want BadMagic, got {other:?}"),
    }

    // a future layout version this build must refuse to guess at
    let mut vnext = bytes.clone();
    vnext[8..12].copy_from_slice(&(checkpoint::VERSION + 1).to_le_bytes());
    let vnext_path = dir.join("vnext.fckpt");
    std::fs::write(&vnext_path, &vnext).unwrap();
    match Checkpoint::read(&vnext_path) {
        Err(CheckpointError::VersionMismatch { found, supported }) => {
            assert_eq!(found, checkpoint::VERSION + 1);
            assert_eq!(supported, checkpoint::VERSION);
        }
        other => panic!("future version: want VersionMismatch, got {other:?}"),
    }

    // and a missing path is NotFound, not a panic or Io guess
    match Checkpoint::read(&dir.join("nope.fckpt")) {
        Err(CheckpointError::NotFound { .. }) => {}
        other => panic!("missing file: want NotFound, got {other:?}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Resuming under a different LR schedule would silently fork the
/// trajectory — the end-to-end resume path must refuse the identity
/// mismatch before any training happens.
#[test]
fn resume_refuses_wrong_schedule_fingerprint() {
    let dir = tmpdir("resume-mismatch");
    Experiment::new("mlp_tiny").k(2).steps(4).seed(1)
        .schedule(ScheduleSpec::Constant).eval_every(4).eval_batches(1)
        .checkpoint_dir(&dir).checkpoint_every(2)
        .run().unwrap();
    let err = Experiment::new("mlp_tiny").k(2).steps(8).seed(1)
        .schedule(ScheduleSpec::InverseT { power: 0.5 })
        .eval_every(4).eval_batches(1)
        .resume_from(&dir)
        .run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("does not match"),
            "want identity-mismatch rejection, got: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

/// Dropping a live fleet (early return, panic unwind, test teardown) must
/// close the channels and join the workers instead of leaking threads or
/// hanging — with and without completed steps.
#[test]
fn dropping_live_fleet_joins_workers_without_hang() {
    let m = manifest_k(4);
    let mut par = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();
    let mut data = DataSource::for_manifest(&m, 2).unwrap();
    par.train_step(&data.train_batch(), 0.01).unwrap();
    par.train_step(&data.train_batch(), 0.01).unwrap();
    drop(par); // no shutdown(): Drop does the orderly teardown

    let par2 = coordinator::parallel::ParallelFr::spawn(
        m, TrainConfig::default(), BackendKind::Native).unwrap();
    drop(par2); // never stepped: workers are idle in cmd recv
}
