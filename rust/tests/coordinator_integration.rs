//! Integration tests of the training strategies' semantic contracts.
//!
//! These run on the native CPU backend with a procedural tiny-MLP manifest,
//! so they exercise the full stack offline — no `make artifacts` needed.
//! (The seed repo's versions self-skipped without artifacts; the native
//! backend is what makes them actually run.)

use features_replay::coordinator::{
    self, make_trainer, Algo, ModuleStack, TrainConfig, Trainer,
};
use features_replay::data::{Batch, DataSource};
use features_replay::optim::ConstantLr;
use features_replay::runtime::{BackendKind, Engine, Manifest, NativeMlpSpec, Tensor};

fn manifest_k(k: usize) -> Manifest {
    NativeMlpSpec::tiny(k).manifest().unwrap()
}

fn load_stack(m: &Manifest, engine: &Engine) -> ModuleStack {
    ModuleStack::load(engine, m.clone(), TrainConfig::default()).unwrap()
}

fn batch_for(manifest: &Manifest, seed: u64) -> Batch {
    let mut data = DataSource::for_manifest(manifest, seed).unwrap();
    data.train_batch()
}

/// FR's *last* module uses the current input and true loss gradient, so its
/// first-step gradient must equal BP's for that module exactly.
#[test]
fn fr_last_module_matches_bp_on_first_step() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let stack = load_stack(&m, &engine);
    let batch = batch_for(&m, 1);

    let (_, bp_grads, _) = stack.bp_grads(&batch).unwrap();

    let mut fr = coordinator::fr::FrTrainer::new(load_stack(&m, &engine));
    let mut fr_grads: Vec<Vec<Tensor>> = Vec::new();
    fr.step_capture(&batch, 0.0, Some(&mut fr_grads)).unwrap();

    let k_last = bp_grads.len() - 1;
    for (a, b) in bp_grads[k_last].iter().zip(&fr_grads[k_last]) {
        let diff: f32 = a.f32s().iter().zip(b.f32s())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-4, "last-module grads differ by {diff}");
    }
}

/// With K=1 there is no decoupling at all: FR, DDG and BP must produce the
/// same parameters after several steps.
#[test]
fn all_methods_equal_bp_at_k1() {
    let m = manifest_k(1);
    let engine = Engine::native();
    let mut data = DataSource::for_manifest(&m, 3).unwrap();
    let batches: Vec<Batch> = (0..3).map(|_| data.train_batch()).collect();

    let mut finals: Vec<Vec<f32>> = Vec::new();
    for algo in [Algo::Bp, Algo::Fr, Algo::Ddg] {
        let mut t = make_trainer(&engine, &m, algo, TrainConfig::default()).unwrap();
        for b in &batches {
            t.train_step(b, 0.01).unwrap();
        }
        finals.push(t.stack().modules[0].params[0].f32s().to_vec());
    }
    for other in &finals[1..] {
        let diff: f32 = finals[0].iter().zip(other)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(diff < 1e-4, "K=1 methods disagree by {diff}");
    }
}

/// After enough identical-lag steps, FR gradients should align with BP
/// (sigma -> positive); weak check: the probe returns finite sane values.
#[test]
fn sigma_probe_produces_sane_values() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let stack = load_stack(&m, &engine);
    let mut fr = coordinator::fr::FrTrainer::new(stack);
    let mut data = DataSource::for_manifest(&m, 5).unwrap();

    let mut last = None;
    for step in 0..6 {
        let batch = data.train_batch();
        let (sample, loss) =
            coordinator::sigma::probe_step(&mut fr, &batch, 0.005, step).unwrap();
        assert!(loss.is_finite());
        assert_eq!(sample.per_module.len(), 4);
        assert!(sample.per_module.iter().all(|s| s.is_finite()));
        last = Some(sample);
    }
    // the last module's direction is exact BP -> sigma == 1
    let s = last.unwrap();
    assert!((s.per_module[3] - 1.0).abs() < 1e-3,
            "last module sigma {} should be 1", s.per_module[3]);
    // after the pipeline warms up, lower-module sigma should be positive
    assert!(s.per_module[0] > -0.5, "sigma way off: {:?}", s.per_module);
}

/// Training must reduce the loss for every method on the tiny MLP.
#[test]
fn short_training_reduces_loss_all_methods() {
    let m = manifest_k(4);
    let engine = Engine::native();

    for algo in [Algo::Bp, Algo::Fr, Algo::Ddg, Algo::Dni] {
        let mut t = make_trainer(&engine, &m, algo, TrainConfig::default()).unwrap();
        let mut data = DataSource::for_manifest(&m, 7).unwrap();
        let mut first = None;
        let mut last = 0.0f32;
        for step in 0..40 {
            let b = data.train_batch();
            let s = t.train_step(&b, 0.004).unwrap();
            if step == 0 {
                first = Some(s.loss);
            }
            last = s.loss;
        }
        let first = first.unwrap();
        assert!(last.is_finite(), "{}: diverged", t.name());
        assert!(last < first,
                "{}: loss did not decrease ({first} -> {last})", t.name());
    }
}

/// The threaded K-worker FR must produce the same training trajectory as the
/// single-timeline FrTrainer (same losses step by step), and its aggregated
/// history accounting must match the sequential trainer's memory report.
#[test]
fn parallel_fr_matches_sequential_fr() {
    let m = manifest_k(4);
    let engine = Engine::native();

    let mut seq = coordinator::fr::FrTrainer::new(load_stack(&m, &engine));
    let mut par = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();

    let mut data1 = DataSource::for_manifest(&m, 11).unwrap();
    let mut data2 = DataSource::for_manifest(&m, 11).unwrap();

    for step in 0..8 {
        let b1 = data1.train_batch();
        let b2 = data2.train_batch();
        let s1 = seq.train_step(&b1, 0.01).unwrap();
        let s2 = par.train_step(&b2, 0.01).unwrap();
        assert!((s1.loss - s2.loss).abs() < 1e-4,
                "step {step}: sequential {} vs parallel {}", s1.loss, s2.loss);
        // the fleet's aggregated replay-ring bytes = the sequential trainer's
        assert_eq!(s1.history_bytes, s2.history_bytes, "step {step}");
    }
    assert_eq!(seq.memory().history,
               par.train_step(&data2.train_batch(), 0.0).unwrap().history_bytes);

    // eval parity too
    let eb = data1.test_batch(0);
    let (l2, e2) = par.eval_batch(&eb).unwrap();
    let hs = seq.stack_ref().forward_chain(&eb.input).unwrap();
    let (l1, a1) = features_replay::metrics::xent_and_acc(hs.last().unwrap(), &eb.labels);
    assert!((l1 - l2).abs() < 1e-6);
    assert!((e2 - (1.0 - a1)).abs() < 1e-9);

    par.shutdown().unwrap();
}

/// A worker whose step fails must surface the root cause through
/// `train_step` — not a bare "worker died mid-step" — and leave the fleet
/// cleanly torn down (later calls fail fast instead of hanging).
#[test]
fn parallel_fr_worker_error_surfaces_root_cause() {
    let m = manifest_k(2);
    let mut par = coordinator::parallel::ParallelFr::spawn(
        m.clone(), TrainConfig::default(), BackendKind::Native).unwrap();
    let mut data = DataSource::for_manifest(&m, 7).unwrap();
    // one good step so every worker is past its iteration-0 paths
    let good = data.train_batch();
    par.train_step(&good, 0.01).unwrap();
    // corrupt the labels: the last worker's fused loss head rejects them
    let mut bad = data.train_batch();
    bad.labels = Tensor::from_i32(vec![3], vec![0, 1, 2]).unwrap();
    let err = par.train_step(&bad, 0.01).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("labels"),
            "error should carry the worker's root cause, got: {msg}");
    // the fleet is down; subsequent steps error cleanly
    let next = data.train_batch();
    let err2 = par.train_step(&next, 0.01).unwrap_err();
    assert!(format!("{err2:#}").contains("shut down"), "{err2:#}");
    par.shutdown().unwrap();
}

/// threads=1 (the exact old single-thread path) and a multi-thread pool
/// must produce bitwise-identical training trajectories: the pool only
/// partitions output rows, it never reorders a float accumulation.
#[test]
fn thread_counts_train_bitwise_identically() {
    let m = manifest_k(2);
    let e1 = Engine::native_with_threads(1);
    let e4 = Engine::native_with_threads(4);
    let mut t1 = coordinator::fr::FrTrainer::new(
        ModuleStack::load(&e1, m.clone(), TrainConfig::default()).unwrap());
    let mut t4 = coordinator::fr::FrTrainer::new(
        ModuleStack::load(&e4, m.clone(), TrainConfig::default()).unwrap());
    let mut d1 = DataSource::for_manifest(&m, 5).unwrap();
    let mut d4 = DataSource::for_manifest(&m, 5).unwrap();
    for step in 0..6 {
        let s1 = t1.train_step(&d1.train_batch(), 0.01).unwrap();
        let s4 = t4.train_step(&d4.train_batch(), 0.01).unwrap();
        assert_eq!(s1.loss.to_bits(), s4.loss.to_bits(),
                   "step {step}: {} vs {}", s1.loss, s4.loss);
    }
}

/// Memory reports: FR holds history+deltas; BP holds only activations; the
/// live DDG stash grows until the pipeline fills.
#[test]
fn memory_reports_reflect_method_structure() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let mut data = DataSource::for_manifest(&m, 1).unwrap();

    let mut bp = make_trainer(&engine, &m, Algo::Bp, TrainConfig::default()).unwrap();
    let mut fr = make_trainer(&engine, &m, Algo::Fr, TrainConfig::default()).unwrap();
    let mut ddg = make_trainer(&engine, &m, Algo::Ddg, TrainConfig::default()).unwrap();
    for _ in 0..5 {
        let b = data.train_batch();
        bp.train_step(&b, 0.01).unwrap();
        fr.train_step(&b, 0.01).unwrap();
        ddg.train_step(&b, 0.01).unwrap();
    }
    let (mb, mf, md) = (bp.memory(), fr.memory(), ddg.memory());
    assert_eq!(mb.history, 0);
    assert!(mf.history > 0 && mf.deltas > 0);
    assert!(md.history > 0 && md.weight_copies > 0);
    assert!(md.total() > mb.total());
}

/// run_training end-to-end: curve recorded, timings collected, no divergence.
#[test]
fn run_training_records_curves() {
    let m = manifest_k(4);
    let engine = Engine::native();
    let mut t = make_trainer(&engine, &m, Algo::Fr, TrainConfig::default()).unwrap();
    let mut data = DataSource::for_manifest(&m, 2).unwrap();
    let opts = coordinator::RunOptions {
        steps: 12, eval_every: 4, eval_batches: 2, steps_per_epoch: 4,
        verbose: false, divergence_loss: 1e4,
    };
    let res = coordinator::run_training(
        t.as_mut(), &mut data, &ConstantLr(0.01), &opts).unwrap();
    assert!(!res.diverged);
    assert!(res.curve.points.len() >= 3);
    assert_eq!(res.timings.len(), 12);
    assert!(res.curve.points.iter().all(|p| p.sim_ms > 0.0));
    assert!(res.final_memory.total() > 0);
}
