//! Integration tests of the declarative Experiment API: every registered
//! model name must resolve and train under every algorithm on the native
//! backend, fully offline (the acceptance bar for the examples), and the
//! builder must surface the run-option knobs it claims to own.

use features_replay::coordinator::{Algo, Trainer};
use features_replay::experiment::{Experiment, ModelRegistry, ScheduleSpec};
use features_replay::runtime::BackendKind;

/// Keep the grid cheap: tiny budgets, constant LR, one eval batch.
fn tiny(model: &str, algo: Algo) -> Experiment {
    Experiment::new(model)
        .k(2)
        .algo(algo)
        .backend(BackendKind::Native)
        .steps(2)
        .eval_every(1)
        .eval_batches(1)
        .schedule(ScheduleSpec::Constant)
}

#[test]
fn every_registered_model_trains_under_every_algo() {
    for entry in ModelRegistry::entries() {
        for algo in Algo::ALL {
            let res = tiny(entry.name, algo).run()
                .unwrap_or_else(|e| panic!("{} x {}: {e:#}", entry.name, algo.name()));
            assert!(!res.curve.points.is_empty(),
                    "{} x {}: empty curve", entry.name, algo.name());
            assert!(res.curve.final_train_loss().is_finite(),
                    "{} x {}: non-finite loss", entry.name, algo.name());
            assert!(!res.diverged, "{} x {}: diverged in 2 steps",
                    entry.name, algo.name());
        }
    }
}

/// The local-loss strategies must actually *learn*, not merely run: over a
/// modest budget the training loss must drop on both an MLP and a conv
/// model (every registry model builds their aux heads — the grid above —
/// but loss descent is the stronger claim worth a dedicated budget).
#[test]
fn local_loss_algos_decrease_training_loss() {
    for model in ["mlp_tiny", "resnet_s"] {
        for algo in [Algo::Dgl, Algo::Backlink] {
            let mut session = Experiment::new(model)
                .k(2)
                .algo(algo)
                .backend(BackendKind::Native)
                .schedule(ScheduleSpec::Constant)
                .lr(0.02)
                .session()
                .unwrap_or_else(|e| panic!("{model} x {}: {e:#}", algo.name()));
            let mut losses = Vec::new();
            for _ in 0..20 {
                let b = session.data.train_batch();
                let stats = session.trainer.train_step(&b, 0.02)
                    .unwrap_or_else(|e| panic!("{model} x {}: {e:#}", algo.name()));
                assert!(stats.loss.is_finite(),
                        "{model} x {}: NaN/inf loss", algo.name());
                losses.push(stats.loss);
            }
            let head: f32 = losses[..3].iter().sum::<f32>() / 3.0;
            let tail: f32 = losses[losses.len() - 3..].iter().sum::<f32>() / 3.0;
            assert!(tail < head,
                    "{model} x {}: loss should decrease ({head:.4} -> {tail:.4})",
                    algo.name());
        }
    }
}

/// The Trainer::traffic contract: global-feedback methods report full
/// backward traffic, DGL reports none, BackLink reports the one-module
/// link — checked through make_trainer so the dispatch stays honest.
#[test]
fn traffic_contract_matches_algorithm_family() {
    use features_replay::coordinator::Traffic;

    for (algo, want) in [
        (Algo::Bp, Traffic::ActivationsAndGrad),
        (Algo::Fr, Traffic::ActivationsAndGrad),
        (Algo::Ddg, Traffic::ActivationsAndGrad),
        (Algo::Dni, Traffic::ActivationsAndGrad),
        (Algo::Dgl, Traffic::ActivationsOnly),
        (Algo::Backlink, Traffic::ActivationsAndLocalGrad),
    ] {
        let session = tiny("mlp_tiny", algo).session().unwrap();
        assert_eq!(session.trainer.traffic(), want,
                   "{} reports the wrong traffic pattern", algo.name());
    }
}

/// Predict-path smoke over the whole registry: every model must accept
/// synthetic samples through `Session::predict_batch` at n = 1 and
/// n = capacity, return one finite logits row per sample, and — the
/// serving contract — give each sample bitwise identical logits whether
/// it runs solo or packed into a full batch.
#[test]
fn every_registered_model_predicts_batched_and_solo_bitwise() {
    use features_replay::runtime::Packer;

    for entry in ModelRegistry::entries() {
        let session = Experiment::new(entry.name)
            .k(2)
            .backend(BackendKind::Native)
            .session()
            .unwrap_or_else(|e| panic!("{}: {e:#}", entry.name));
        let packer = Packer::new(&session.manifest).unwrap();
        let n = packer.capacity();
        let samples: Vec<_> = (0..n).map(|i| packer.synthetic_sample(i)).collect();

        let batched = session.predict_batch(&samples)
            .unwrap_or_else(|e| panic!("{}: batched predict: {e:#}", entry.name));
        assert_eq!(batched.len(), n, "{}: one row per sample", entry.name);
        for (i, row) in batched.iter().enumerate() {
            assert_eq!(row.len(), packer.logits_per_sample(),
                       "{}: row {i} length", entry.name);
            assert!(row.iter().all(|v| v.is_finite()),
                    "{}: non-finite logit in row {i}", entry.name);
        }

        // solo runs must reproduce the batched rows bit for bit
        for (i, sample) in samples.iter().enumerate().take(2.min(n)) {
            let solo = session.predict_batch(std::slice::from_ref(sample))
                .unwrap_or_else(|e| panic!("{}: solo predict: {e:#}", entry.name));
            let solo_bits: Vec<u32> = solo[0].iter().map(|v| v.to_bits()).collect();
            let batch_bits: Vec<u32> = batched[i].iter().map(|v| v.to_bits()).collect();
            assert_eq!(solo_bits, batch_bits,
                       "{}: sample {i} logits differ solo vs batched", entry.name);
        }
    }
}

#[test]
fn eval_cadence_controls_curve_density() {
    let res = Experiment::new("mlp_tiny")
        .k(2)
        .backend(BackendKind::Native)
        .steps(5)
        .eval_every(2)
        .eval_batches(1)
        .schedule(ScheduleSpec::Constant)
        .run()
        .unwrap();
    // evals at steps 0, 2, 4 (4 is also the final step)
    assert_eq!(res.curve.points.len(), 3);
    let steps: Vec<usize> = res.curve.points.iter().map(|p| p.step).collect();
    assert_eq!(steps, vec![0, 2, 4]);
}

#[test]
fn divergence_threshold_is_surfaced_through_builder() {
    // any positive loss trips a 1e-9 threshold on the first step
    let res = Experiment::new("mlp_tiny")
        .k(2)
        .backend(BackendKind::Native)
        .steps(3)
        .divergence_loss(1e-9)
        .run()
        .unwrap();
    assert!(res.diverged);
    assert_eq!(res.curve.points.len(), 1, "aborts on the first step");
}

#[test]
fn unknown_model_error_names_the_registry() {
    let err = Experiment::new("resnet_xxl").run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("resnet_xxl"), "{msg}");
    assert!(msg.contains("mlp_tiny"), "should list registered names: {msg}");
}

#[test]
fn session_exposes_trainer_and_data_for_manual_stepping() {
    let mut session = Experiment::new("transformer_tiny")
        .k(2)
        .algo(Algo::Fr)
        .backend(BackendKind::Native)
        .session()
        .unwrap();
    assert_eq!(session.backend, BackendKind::Native);
    for _ in 0..2 {
        let b = session.data.train_batch();
        let stats = session.trainer.train_step(&b, 0.01).unwrap();
        assert!(stats.loss.is_finite());
    }
    assert!(session.trainer.memory().total() > 0);
}

#[test]
fn fr_session_drives_the_sigma_probe() {
    use features_replay::coordinator::sigma;

    let mut fs = Experiment::new("mlp_tiny")
        .k(3)
        .backend(BackendKind::Native)
        .build_fr()
        .unwrap();
    let batch = fs.data.train_batch();
    let (s, loss) = sigma::probe_step(&mut fs.fr, &batch, 0.01, 0).unwrap();
    assert!(loss.is_finite());
    assert_eq!(s.per_module.len(), 3);
    // the last module is exact BP, so its sigma is 1 by construction
    assert!((s.per_module[2] - 1.0).abs() < 1e-3,
            "sigma_K = {}", s.per_module[2]);
}

#[test]
fn parallel_session_runs_and_shuts_down() {
    let mut ps = Experiment::new("mlp_tiny")
        .k(2)
        .backend(BackendKind::Native)
        .spawn_parallel()
        .unwrap();
    assert_eq!(ps.par.k(), 2);
    for _ in 0..2 {
        let b = ps.data.train_batch();
        let stats = ps.par.train_step(&b, 0.01).unwrap();
        assert!(stats.loss.is_finite());
    }
    ps.par.shutdown().unwrap();
}

#[test]
fn char_lm_transformer_trains_on_token_stream() {
    // the Embed + causal-attention path end to end: i32 tokens in,
    // per-position labels out
    let res = Experiment::new("transformer_tiny")
        .k(4)
        .algo(Algo::Fr)
        .backend(BackendKind::Native)
        .steps(3)
        .lr(3e-3)
        .eval_every(1)
        .eval_batches(1)
        .schedule(ScheduleSpec::Constant)
        .run()
        .unwrap();
    assert!(!res.diverged);
    assert!(res.curve.final_train_loss().is_finite());
    // untrained char-LM loss starts near ln(96) ~ 4.56; 3 steps keep it sane
    assert!(res.curve.points[0].train_loss < 10.0);
}
