//! End-to-end tests of `frctl serve` over real sockets: the acceptance
//! criterion that a coalesced micro-batch of N concurrent predict
//! requests returns results bitwise identical to the same N served one
//! at a time (at kernel threads 1 and max), plus endpoint coverage —
//! typed 400s for malformed input, metrics/health bodies, and a
//! background train-job lifecycle smoke.

use std::sync::atomic::Ordering;
use std::sync::{Arc, Barrier};
use std::time::{Duration, Instant};

use features_replay::experiment::Experiment;
use features_replay::runtime::Packer;
use features_replay::serve::http::MiniClient;
use features_replay::serve::{ServeConfig, Server};
use features_replay::util::json::Json;

/// Bind an in-process server on an ephemeral port and run it on a
/// background thread; returns (addr, stop-closure).
fn start_server(mut cfg: ServeConfig) -> (String, impl FnOnce()) {
    cfg.addr = "127.0.0.1:0".to_string();
    let server = Server::bind(cfg).expect("bind");
    let addr = server.local_addr().to_string();
    let stop = server.stop_handle();
    let handle = std::thread::spawn(move || server.run());
    wait_healthy(&addr);
    (addr, move || {
        stop.store(true, Ordering::Relaxed);
        handle.join().expect("server thread").expect("clean shutdown");
    })
}

fn wait_healthy(addr: &str) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while Instant::now() < deadline {
        if let Ok((200, _)) = MiniClient::one_shot(addr, "GET", "/healthz", b"") {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("server at {addr} never became healthy");
}

fn predict_body(packer: &Packer, i: usize) -> Vec<u8> {
    use features_replay::runtime::Sample;
    let mut out = String::new();
    match packer.synthetic_sample(i) {
        Sample::F32(v) => {
            out.push_str("{\"input\":[");
            let vals: Vec<String> = v.iter().map(|x| format!("{}", *x as f64)).collect();
            out.push_str(&vals.join(","));
        }
        Sample::Tokens(v) => {
            out.push_str("{\"tokens\":[");
            let vals: Vec<String> = v.iter().map(|t| t.to_string()).collect();
            out.push_str(&vals.join(","));
        }
    }
    out.push_str("]}");
    out.into_bytes()
}

/// Parse a 200 predict body into (logit bit patterns, batch field).
fn parse_predict(body: &[u8]) -> (Vec<u64>, usize) {
    let json = Json::parse(std::str::from_utf8(body).unwrap()).unwrap();
    let logits = json.get("logits").unwrap().as_arr().unwrap().iter()
        .map(|v| v.as_f64().unwrap().to_bits())
        .collect();
    let batch = json.get("batch").unwrap().as_usize().unwrap();
    (logits, batch)
}

/// The tentpole acceptance test: N requests served solo (each its own
/// micro-batch) must produce bitwise identical logits to the same N
/// requests arriving concurrently and coalescing — at both the
/// single-thread kernel reference and max threads.
#[test]
fn coalesced_batches_match_solo_bitwise() {
    let n = 4usize;
    let packer = Packer::new(
        &Experiment::new("mlp_tiny").k(2).manifest().unwrap()).unwrap();
    for threads in [1usize, 0] {
        let mut cfg = ServeConfig::new("mlp_tiny");
        cfg.k = 2;
        cfg.threads = threads;
        cfg.max_batch = n;
        // long enough that concurrent requests coalesce; solo requests pay
        // it once each and flush alone
        cfg.max_wait_ms = 200;
        cfg.jobs_dir = std::env::temp_dir()
            .join(format!("frctl-serve-test-{}-{threads}", std::process::id()));
        let (addr, shutdown) = start_server(cfg);

        // phase 1: one at a time — every response must say batch=1
        let mut solo: Vec<Vec<u64>> = Vec::new();
        for i in 0..n {
            let (status, body) = MiniClient::one_shot(
                &addr, "POST", "/v1/predict", &predict_body(&packer, i)).unwrap();
            assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
            let (logits, batch) = parse_predict(&body);
            assert_eq!(batch, 1, "solo request must flush alone");
            assert_eq!(logits.len(), packer.logits_per_sample());
            solo.push(logits);
        }

        // phase 2: the same n requests at once, released by a barrier
        let barrier = Arc::new(Barrier::new(n));
        let handles: Vec<_> = (0..n).map(|i| {
            let addr = addr.clone();
            let barrier = Arc::clone(&barrier);
            let body = predict_body(&packer, i);
            std::thread::spawn(move || {
                let mut client = MiniClient::connect(&addr).unwrap();
                barrier.wait();
                let (status, resp) = client.request("POST", "/v1/predict", &body)
                    .unwrap();
                assert_eq!(status, 200, "{}", String::from_utf8_lossy(&resp));
                parse_predict(&resp)
            })
        }).collect();
        let concurrent: Vec<(Vec<u64>, usize)> = handles.into_iter()
            .map(|h| h.join().unwrap())
            .collect();

        // any partition into micro-batches keeps logits bitwise identical;
        // with a 200 ms hold the batcher must still have coalesced some
        let max_batch = concurrent.iter().map(|(_, b)| *b).max().unwrap();
        assert!(max_batch >= 2,
                "threads={threads}: no coalescing observed (max batch {max_batch})");
        for (i, (logits, _)) in concurrent.iter().enumerate() {
            assert_eq!(logits, &solo[i],
                       "threads={threads}: sample {i} differs between solo \
                        and coalesced serving");
        }
        shutdown();
    }
}

#[test]
fn malformed_predicts_are_typed_400s() {
    let mut cfg = ServeConfig::new("transformer_tiny");
    cfg.k = 2;
    cfg.max_wait_ms = 1;
    cfg.jobs_dir = std::env::temp_dir()
        .join(format!("frctl-serve-test-400-{}", std::process::id()));
    let (addr, shutdown) = start_server(cfg);

    // wrong input kind for a token model
    let (status, body) = MiniClient::one_shot(
        &addr, "POST", "/v1/predict", br#"{"input": [1.0, 2.0]}"#).unwrap();
    assert_eq!(status, 400);
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(json.get("detail").unwrap().as_str().unwrap().contains("token"));

    // wrong length
    let (status, _) = MiniClient::one_shot(
        &addr, "POST", "/v1/predict", br#"{"tokens": [1, 2, 3]}"#).unwrap();
    assert_eq!(status, 400);

    // out-of-vocab token (vocab 96) — must be a 400, not a kernel panic
    let toks: Vec<String> = (0..32).map(|_| "500".to_string()).collect();
    let body_bytes = format!("{{\"tokens\":[{}]}}", toks.join(","));
    let (status, body) = MiniClient::one_shot(
        &addr, "POST", "/v1/predict", body_bytes.as_bytes()).unwrap();
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));

    // malformed JSON
    let (status, _) = MiniClient::one_shot(
        &addr, "POST", "/v1/predict", b"{not json").unwrap();
    assert_eq!(status, 400);

    // unknown route and wrong method
    let (status, _) = MiniClient::one_shot(&addr, "GET", "/nope", b"").unwrap();
    assert_eq!(status, 404);
    let (status, _) = MiniClient::one_shot(&addr, "GET", "/v1/predict", b"").unwrap();
    assert_eq!(status, 405);

    // after all that abuse the server still answers health + metrics
    let (status, body) = MiniClient::one_shot(&addr, "GET", "/v1/metrics", b"").unwrap();
    assert_eq!(status, 200);
    let metrics = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(metrics.get("predict_errors").unwrap().as_usize().unwrap() >= 4);
    assert!(metrics.get("request_latency").unwrap().get("count").is_some());
    shutdown();
}

#[test]
fn keep_alive_connection_serves_many_requests() {
    let mut cfg = ServeConfig::new("mlp_tiny");
    cfg.k = 2;
    cfg.max_wait_ms = 1;
    cfg.jobs_dir = std::env::temp_dir()
        .join(format!("frctl-serve-test-ka-{}", std::process::id()));
    let packer = Packer::new(
        &Experiment::new("mlp_tiny").k(2).manifest().unwrap()).unwrap();
    let (addr, shutdown) = start_server(cfg);
    let mut client = MiniClient::connect(&addr).unwrap();
    for i in 0..5 {
        let (status, _) = client
            .request("POST", "/v1/predict", &predict_body(&packer, i)).unwrap();
        assert_eq!(status, 200);
        let (status, _) = client.request("GET", "/healthz", b"").unwrap();
        assert_eq!(status, 200);
    }
    shutdown();
}

/// The `algo` field rides the same typed table as `frctl --algo`: an
/// unknown name is a 400 whose detail lists every valid name (never a 500
/// from deep inside a job thread), and a local-loss job (`dgl`) runs the
/// sequential path to "done" with the same NDJSON stream the FR fleet
/// path produces.
#[test]
fn train_job_algo_is_typed_and_dgl_runs_to_done() {
    use features_replay::coordinator::Algo;

    let mut cfg = ServeConfig::new("mlp_tiny");
    cfg.k = 2;
    cfg.max_wait_ms = 1;
    cfg.jobs_dir = std::env::temp_dir()
        .join(format!("frctl-serve-test-algo-{}", std::process::id()));
    let (addr, shutdown) = start_server(cfg);

    // unknown algo → typed 400 naming every valid choice
    let (status, body) = MiniClient::one_shot(
        &addr, "POST", "/v1/train-jobs",
        br#"{"model": "mlp_tiny", "algo": "sgd"}"#).unwrap();
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let detail = json.get("detail").unwrap().as_str().unwrap().to_string();
    for a in Algo::ALL {
        assert!(detail.contains(a.cli_name()),
                "400 detail must list {:?}: {detail}", a.cli_name());
    }

    // a non-string algo is a 400 too, not a decoder panic
    let (status, _) = MiniClient::one_shot(
        &addr, "POST", "/v1/train-jobs",
        br#"{"model": "mlp_tiny", "algo": 7}"#).unwrap();
    assert_eq!(status, 400);

    // a dgl job takes the sequential path end to end
    let (status, body) = MiniClient::one_shot(
        &addr, "POST", "/v1/train-jobs",
        br#"{"model": "mlp_tiny", "algo": "dgl", "k": 2, "steps": 3}"#).unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let id = json.get("id").unwrap().as_usize().unwrap();

    let deadline = Instant::now() + Duration::from_secs(60);
    let final_state = loop {
        let (status, body) = MiniClient::one_shot(
            &addr, "GET", &format!("/v1/train-jobs/{id}"), b"").unwrap();
        assert_eq!(status, 200);
        let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let state = json.get("state").unwrap().as_str().unwrap().to_string();
        if state != "running" {
            break json;
        }
        assert!(Instant::now() < deadline, "dgl job {id} never finished");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(final_state.get("state").unwrap().as_str(), Some("done"),
               "{final_state:?}");
    assert_eq!(final_state.get("step").unwrap().as_usize(), Some(3));
    assert_eq!(final_state.get("spec").unwrap().get("algo").unwrap().as_str(),
               Some("dgl"));
    assert!(final_state.get("eval_loss").unwrap().as_f64().unwrap().is_finite());

    // the sequential path streams the same NDJSON shape as the fleet path
    let (status, body) = MiniClient::one_shot(
        &addr, "GET", &format!("/v1/train-jobs/{id}/metrics"), b"").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 3, "{text}");
    for (i, line) in lines.iter().enumerate() {
        let step = Json::parse(line).unwrap();
        assert_eq!(step.get("step").unwrap().as_usize(), Some(i));
        assert!(step.get("loss").unwrap().as_f64().unwrap().is_finite());
    }
    shutdown();
}

#[test]
fn train_job_lifecycle_streams_metrics() {
    let mut cfg = ServeConfig::new("mlp_tiny");
    cfg.k = 2;
    cfg.max_wait_ms = 1;
    cfg.jobs_dir = std::env::temp_dir()
        .join(format!("frctl-serve-test-jobs-{}", std::process::id()));
    let (addr, shutdown) = start_server(cfg);

    // bad spec → 400 before any thread spawns
    let (status, _) = MiniClient::one_shot(
        &addr, "POST", "/v1/train-jobs", br#"{"steps": 3}"#).unwrap();
    assert_eq!(status, 400);

    let (status, body) = MiniClient::one_shot(
        &addr, "POST", "/v1/train-jobs",
        br#"{"model": "mlp_tiny", "k": 2, "steps": 3, "threads": 1}"#).unwrap();
    assert_eq!(status, 202, "{}", String::from_utf8_lossy(&body));
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let id = json.get("id").unwrap().as_usize().unwrap();

    // poll the status endpoint until the job finishes (bounded)
    let deadline = Instant::now() + Duration::from_secs(60);
    let final_state = loop {
        let (status, body) = MiniClient::one_shot(
            &addr, "GET", &format!("/v1/train-jobs/{id}"), b"").unwrap();
        assert_eq!(status, 200);
        let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        let state = json.get("state").unwrap().as_str().unwrap().to_string();
        if state != "running" {
            break json;
        }
        assert!(Instant::now() < deadline, "job {id} never finished");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert_eq!(final_state.get("state").unwrap().as_str(), Some("done"),
               "{final_state:?}");
    assert_eq!(final_state.get("step").unwrap().as_usize(), Some(3));
    assert!(final_state.get("eval_loss").unwrap().as_f64().unwrap().is_finite());

    // the NDJSON stream has one parseable line per step with a loss
    let (status, body) = MiniClient::one_shot(
        &addr, "GET", &format!("/v1/train-jobs/{id}/metrics"), b"").unwrap();
    assert_eq!(status, 200);
    let text = String::from_utf8(body).unwrap();
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), 3, "{text}");
    for (i, line) in lines.iter().enumerate() {
        let step = Json::parse(line).unwrap();
        assert_eq!(step.get("step").unwrap().as_usize(), Some(i));
        assert!(step.get("loss").unwrap().as_f64().unwrap().is_finite());
    }

    // unknown job id
    let (status, _) = MiniClient::one_shot(
        &addr, "GET", "/v1/train-jobs/999", b"").unwrap();
    assert_eq!(status, 404);

    // list shows the job
    let (status, body) = MiniClient::one_shot(
        &addr, "GET", "/v1/train-jobs", b"").unwrap();
    assert_eq!(status, 200);
    let json = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(json.get("jobs").unwrap().as_arr().unwrap().len(), 1);
    shutdown();
}
