//! End-to-end iteration benchmarks — the Fig 4 row 2 / Fig 6 cost source:
//! full train_step latency of each method on each model, plus the derived
//! K-device pipeline numbers (BP vs FR speedup, BP-DP scaling).

use features_replay::bench::Bencher;
use features_replay::coordinator::{
    self, make_trainer, pipeline_sim, Algo, TrainConfig,
};
use features_replay::data::DataSource;
use features_replay::runtime::{Engine, Manifest};

fn main() {
    let root = features_replay::default_artifacts_root();
    let mut b = Bencher::new();
    let comm = pipeline_sim::CommModel::default();

    for cfg in ["mlp_tiny_k4", "resnet_s_k4"] {
        let dir = root.join(cfg);
        if !dir.exists() {
            eprintln!("(skip {cfg}: artifacts not built)");
            continue;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        println!("\n-- {cfg}: one training iteration per method --");

        for algo in [Algo::Bp, Algo::Fr, Algo::Ddg, Algo::Dni] {
            let mut trainer = make_trainer(&engine, &dir, algo,
                                           TrainConfig::default()).unwrap();
            let mut data = DataSource::for_manifest(&manifest, 0).unwrap();
            // warm the pipeline so steady-state is measured
            for _ in 0..manifest.k {
                let batch = data.train_batch();
                trainer.train_step(&batch, 0.01).unwrap();
            }
            let mut timings = Vec::new();
            let batch = data.train_batch();
            b.bench(&format!("{cfg}/{}/train_step", trainer.name()), || {
                let s = trainer.train_step(&batch, 0.01).unwrap();
                timings.push(s.timing);
            });
            let costs = pipeline_sim::MeasuredCosts::from_timings(
                &timings,
                coordinator::boundary_bytes(trainer.stack()),
                coordinator::param_bytes(trainer.stack()));
            match algo {
                Algo::Bp => {
                    println!("    K-device locked BP : {:8.2} ms/iter",
                             pipeline_sim::bp_iteration_ms(&costs, &comm));
                    for n in [2, 4] {
                        println!("    BP data-parallel x{n}: {:8.2} ms/iter",
                                 pipeline_sim::bp_data_parallel_ms(&costs, &comm, n));
                    }
                }
                Algo::Fr => {
                    println!("    K-device FR        : {:8.2} ms/iter  (speedup {:.2}x)",
                             pipeline_sim::decoupled_iteration_ms(&costs, &comm),
                             pipeline_sim::fr_speedup(&costs, &comm));
                }
                _ => {
                    println!("    K-device decoupled : {:8.2} ms/iter",
                             pipeline_sim::decoupled_iteration_ms(&costs, &comm));
                }
            }
        }
    }
}
