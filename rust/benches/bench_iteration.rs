//! End-to-end iteration benchmarks — the Fig 4 row 2 / Fig 6 cost source:
//! full train_step latency of each method on the native CPU backend, the
//! derived K-device pipeline numbers (BP vs FR speedup, BP-DP scaling), and
//! the hot-path copy audit written to BENCH_hotpath.json:
//!
//! - `fr_deep_copy_bytes_per_step` must be 0 — the replay/channel path is
//!   Arc clones only (copy-on-write never fires during FR),
//! - `fr_param_remarshals_per_step` must be 0 — parameters stay resident in
//!   the backend instead of being re-marshaled every `run`.

use std::path::PathBuf;

use features_replay::bench::{write_bench_json, Bencher};
use features_replay::coordinator::{
    self, make_trainer, parallel::ParallelFr, pipeline_sim, Algo, TrainConfig, Trainer,
};
use features_replay::data::DataSource;
use features_replay::runtime::{copy_metrics, BackendKind, NativeMlpSpec};
use features_replay::util::json::{num, s, Json};

const AUDIT_STEPS: usize = 16;

fn main() {
    let manifest = NativeMlpSpec::tiny(4).manifest().unwrap();
    let engine = BackendKind::Native.engine().unwrap();
    let mut b = Bencher::new();
    let comm = pipeline_sim::CommModel::default();
    println!("-- {} ({} backend): one training iteration per method --",
             manifest.config, engine.platform());

    let mut extra: Vec<(&str, Json)> = vec![
        ("backend", s(&engine.platform())),
        ("config", s(&manifest.config)),
    ];

    for algo in [Algo::Bp, Algo::Fr, Algo::Ddg, Algo::Dni] {
        let mut trainer = make_trainer(&engine, &manifest, algo,
                                       TrainConfig::default()).unwrap();
        let mut data = DataSource::for_manifest(&manifest, 0).unwrap();
        // warm the pipeline so steady-state is measured
        for _ in 0..manifest.k {
            let batch = data.train_batch();
            trainer.train_step(&batch, 0.01).unwrap();
        }
        let mut timings = Vec::new();
        let batch = data.train_batch();
        b.bench(&format!("{}/train_step", trainer.name()), || {
            let stats = trainer.train_step(&batch, 0.01).unwrap();
            timings.push(stats.timing);
        });
        let costs = pipeline_sim::MeasuredCosts::from_timings(
            &timings,
            coordinator::boundary_bytes(trainer.stack()),
            coordinator::param_bytes(trainer.stack()));
        match algo {
            Algo::Bp => {
                println!("    K-device locked BP : {:8.3} ms/iter",
                         pipeline_sim::bp_iteration_ms(&costs, &comm));
                for n in [2, 4] {
                    println!("    BP data-parallel x{n}: {:8.3} ms/iter",
                             pipeline_sim::bp_data_parallel_ms(&costs, &comm, n));
                }
            }
            Algo::Fr => {
                println!("    K-device FR        : {:8.3} ms/iter  (speedup {:.2}x)",
                         pipeline_sim::decoupled_iteration_ms(&costs, &comm),
                         pipeline_sim::fr_speedup(&costs, &comm));
            }
            _ => {
                println!("    K-device decoupled : {:8.3} ms/iter",
                         pipeline_sim::decoupled_iteration_ms(&costs, &comm));
            }
        }

        // Hot-path copy audit for FR: after warmup, a steady-state window
        // must perform zero deep copies and zero parameter re-marshals.
        if algo == Algo::Fr {
            copy_metrics::reset();
            let mut history_bytes = 0usize;
            for _ in 0..AUDIT_STEPS {
                let batch = data.train_batch();
                let stats = trainer.train_step(&batch, 0.01).unwrap();
                history_bytes = stats.history_bytes;
            }
            let per = AUDIT_STEPS as f64;
            extra.push(("fr_deep_copies_per_step",
                        num(copy_metrics::deep_copies() as f64 / per)));
            extra.push(("fr_deep_copy_bytes_per_step",
                        num(copy_metrics::deep_copy_bytes() as f64 / per)));
            extra.push(("fr_param_remarshals_per_step",
                        num(copy_metrics::param_remarshals() as f64 / per)));
            extra.push(("fr_arc_clones_per_step",
                        num(copy_metrics::shallow_clones() as f64 / per)));
            extra.push(("fr_history_bytes", num(history_bytes as f64)));
            println!("    FR copy audit      : {:.1} deep-copy B/step, \
                      {:.1} remarshals/step, {:.1} arc clones/step",
                     copy_metrics::deep_copy_bytes() as f64 / per,
                     copy_metrics::param_remarshals() as f64 / per,
                     copy_metrics::shallow_clones() as f64 / per);
        }
    }

    // Threaded deployment: the channel path must be zero-copy too.
    {
        let mut data = DataSource::for_manifest(&manifest, 0).unwrap();
        let mut par = ParallelFr::spawn(
            manifest.clone(), TrainConfig::default(), BackendKind::Native).unwrap();
        for _ in 0..manifest.k {
            let batch = data.train_batch();
            par.train_step(&batch, 0.01).unwrap();
        }
        copy_metrics::reset();
        let batch = data.train_batch();
        b.bench("ParallelFR/train_step", || {
            par.train_step(&batch, 0.01).unwrap();
        });
        let steps = b.warmup_iters + b.results.last().map(|r| r.iters).unwrap_or(1);
        extra.push(("parallel_deep_copy_bytes_per_step",
                    num(copy_metrics::deep_copy_bytes() as f64 / steps as f64)));
        par.shutdown().unwrap();
    }

    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..").join("BENCH_hotpath.json");
    write_bench_json(&out, "hotpath", &b.results, extra).unwrap();
    println!("\nwrote {}", out.display());
}
