//! End-to-end serving benchmark: an in-process `frctl serve` on an
//! ephemeral port, closed-loop keep-alive clients at concurrency {1, 4},
//! exact p50/p95/p99 request latency + requests/sec written to
//! `BENCH_serve.json` at the repo root (per-machine artifact — generated,
//! not committed).
//!
//! Run with `cargo bench --bench bench_serve` (FR_BENCH_QUICK=1 for a
//! fast pass) or `scripts/ci.sh --bench`.

use std::path::PathBuf;

fn main() {
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..").join("BENCH_serve.json");
    features_replay::bench::serve::run_serve_bench(&out).unwrap();
}
