//! Runtime-layer benchmarks on the native CPU backend: per-module program
//! latency (fwd / bwd / fused loss head) plus the raw kernel hot-spots.
//!
//! This is the "measured cost" source: everything the pipeline simulator
//! consumes is visible here. Run with `cargo bench` (or FR_BENCH_QUICK=1
//! for a fast pass).

use features_replay::bench::Bencher;
use features_replay::runtime::native::kernels;
use features_replay::runtime::{DType, Engine, ModuleRuntime, NativeMlpSpec, Tensor};

fn main() {
    let mut b = Bencher::new();
    let manifest = NativeMlpSpec::tiny(4).manifest().unwrap();
    let engine = Engine::native();
    println!("-- {} ({}) --", manifest.config, engine.platform());

    for k in 0..manifest.k {
        let m = ModuleRuntime::load(&engine, &manifest, k).unwrap();
        let h = Tensor::zeros(&m.spec.in_shape, m.spec.in_dtype);
        if k < manifest.k - 1 {
            b.bench(&format!("module{k}/fwd"), || {
                m.forward(&h).unwrap();
            });
            let delta = Tensor::zeros(&m.spec.out_shape, DType::F32);
            b.bench(&format!("module{k}/bwd"), || {
                m.backward(&h, &delta).unwrap();
            });
        } else {
            let labels = Tensor::from_i32(
                manifest.label_shape.clone(),
                vec![0; manifest.label_shape.iter().product()]).unwrap();
            b.bench(&format!("module{k}/loss_bwd"), || {
                m.loss_backward(&h, &labels).unwrap();
            });
        }
    }

    // raw kernel hot-spots at the stem's dimensions
    let (bb, din, h) = (16usize, 3072usize, 64usize);
    let x = vec![0.5f32; bb * din];
    let w = vec![0.01f32; din * h];
    b.bench("kernels/matmul 16x3072x64", || {
        let _ = kernels::matmul(&x, &w, bb, din, h);
    });
    let dy = vec![0.5f32; bb * h];
    b.bench("kernels/matmul_tn (dW)", || {
        let _ = kernels::matmul_tn(&x, &dy, bb, din, h);
    });
    b.bench("kernels/matmul_nt (dx)", || {
        let _ = kernels::matmul_nt(&dy, &w, bb, h, din);
    });

    // host-tensor traffic: Arc clone vs forced deep copy
    let big = Tensor::zeros(&[32, 32, 32, 3], DType::F32);
    b.bench("tensor/arc_clone (393 KB)", || {
        let _ = big.clone();
    });
    b.bench("tensor/deep_copy_via_cow (393 KB)", || {
        let mut c = big.clone();
        c.f32s_mut()[0] = 1.0; // shared -> copy-on-write fires
    });
}
