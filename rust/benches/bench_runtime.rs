//! Runtime-layer benchmarks: per-module executable latency (fwd / bwd /
//! fused loss head) and host<->literal marshaling, per artifact config.
//!
//! This is the L1/L2 "measured cost" source: everything the pipeline
//! simulator consumes is visible here. Run with `cargo bench` (or
//! FR_BENCH_QUICK=1 for a fast pass).

use features_replay::bench::Bencher;
use features_replay::runtime::{DType, Engine, Manifest, ModuleRuntime, Tensor};

fn main() {
    let root = features_replay::default_artifacts_root();
    let mut b = Bencher::new();

    for cfg in ["mlp_tiny_k4", "resnet_s_k4", "transformer_tiny_k4"] {
        let dir = root.join(cfg);
        if !dir.exists() {
            eprintln!("(skip {cfg}: artifacts not built)");
            continue;
        }
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        println!("\n-- {cfg} --");
        for k in 0..manifest.k {
            let m = ModuleRuntime::load(&engine, &manifest, k).unwrap();
            let h = Tensor::zeros(&m.spec.in_shape, m.spec.in_dtype);
            if k < manifest.k - 1 {
                b.bench(&format!("{cfg}/module{k}/fwd"), || {
                    m.forward(&h).unwrap();
                });
            }
            let delta = Tensor::zeros(&m.spec.out_shape, DType::F32);
            if k < manifest.k - 1 {
                b.bench(&format!("{cfg}/module{k}/bwd"), || {
                    m.backward(&h, &delta).unwrap();
                });
            } else {
                let labels = Tensor::from_i32(
                    manifest.label_shape.clone(),
                    vec![0; manifest.label_shape.iter().product()]).unwrap();
                b.bench(&format!("{cfg}/module{k}/loss_bwd"), || {
                    m.loss_backward(&h, &labels).unwrap();
                });
            }
        }

        // marshaling overhead: the L3 <-> PJRT boundary cost
        let big = Tensor::zeros(&manifest.input_shape, manifest.input_dtype);
        b.bench(&format!("{cfg}/tensor_to_literal"), || {
            big.to_literal().unwrap();
        });
        let lit = big.to_literal().unwrap();
        b.bench(&format!("{cfg}/literal_to_tensor"), || {
            Tensor::from_literal(&lit).unwrap();
        });
    }
}
