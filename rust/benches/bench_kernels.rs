//! Thread-count sweep of the pool-partitioned native kernels: times the
//! matmul family, im2col/col2im, the group-parallel attention kernels, and
//! full resnet_s + transformer_tiny module fwd/bwd steps at `threads = 1`
//! (the bitwise single-thread reference) and `threads = max` (available
//! parallelism), then writes `BENCH_kernels.json` at the repo root — the
//! perf-trajectory artifact later PRs diff against.
//!
//! Run with `cargo bench --bench bench_kernels` (FR_BENCH_QUICK=1 for a
//! fast pass) or `scripts/ci.sh --bench`.

use std::path::PathBuf;

fn main() {
    let out = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("..").join("BENCH_kernels.json");
    let report = features_replay::bench::kernels::run_kernel_sweep(&out).unwrap();
    if report.threads.len() == 2 {
        let worst = report.speedups.iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        if let Some((name, sp)) = worst {
            println!("slowest-scaling kernel: {name} at {sp:.2}x");
        }
    }
}
