//! L3 coordinator micro-benchmarks: the non-compute overheads that must stay
//! under 10% of module compute per DESIGN.md §Perf — replay-buffer traffic,
//! optimizer updates, channel round-trips, JSON parsing, data generation.

use features_replay::bench::Bencher;
use features_replay::coordinator::history::ReplayBuffer;
use features_replay::data::synthetic_cifar::SyntheticCifar;
use features_replay::data::tiny_corpus::TinyCorpus;
use features_replay::optim::SgdMomentum;
use features_replay::runtime::{DType, Tensor};
use features_replay::util::json::Json;

fn main() {
    let mut b = Bencher::new();

    // replay ring: push + stale on a CIFAR-sized boundary tensor — with
    // Arc-backed tensors this is refcount traffic, not a memcpy
    let shape = [32usize, 16, 16, 32];
    let mut ring = ReplayBuffer::new(4, &shape, DType::F32);
    let t = Tensor::zeros(&shape, DType::F32);
    b.bench("history/push+stale (1 MB tensor)", || {
        ring.push(t.clone());
        let _ = ring.stale(3).len();
    });

    // optimizer: SGD+momentum over 1M params
    let mut params = vec![Tensor::zeros(&[1_000_000], DType::F32)];
    let grads = vec![Tensor::zeros(&[1_000_000], DType::F32)];
    let mut opt = SgdMomentum::new(&params, 0.9, 5e-4);
    b.bench("optimizer/sgd_momentum (1M params)", || {
        opt.step(&mut params, &grads, 0.01).unwrap();
    });

    // channel round-trip with a boundary-sized payload (worker hand-off)
    let (tx, rx) = std::sync::mpsc::channel::<Tensor>();
    b.bench("channel/send+recv (1 MB tensor)", || {
        tx.send(t.clone()).unwrap();
        let _ = rx.recv().unwrap();
    });

    // manifest parse (startup path)
    let manifest_path = features_replay::default_artifacts_root()
        .join("resnet_s_k4").join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        b.bench("json/parse resnet_s manifest", || {
            Json::parse(&text).unwrap();
        });
    }

    // data generation (must stay off the critical path)
    let mut cifar = SyntheticCifar::new(10, 0);
    b.bench("data/synthetic_cifar batch 32", || {
        let _ = cifar.train_batch(32);
    });
    let mut corpus = TinyCorpus::new(200_000, 0);
    b.bench("data/tiny_corpus batch 8x64", || {
        let _ = corpus.train_batch(8, 64);
    });

    // batch-scale tensor hand-off (what every channel send now costs)
    let batchy = Tensor::zeros(&[32, 32, 32, 3], DType::F32);
    b.bench("tensor/arc_clone (393 KB)", || {
        let _ = batchy.clone();
    });
}
