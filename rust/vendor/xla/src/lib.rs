//! Offline stub of the `xla` PJRT bindings.
//!
//! The sandbox has neither the XLA C++ runtime nor registry access, so this
//! crate exists to keep the `pjrt` cargo feature *compilable*: it mirrors
//! the exact API surface `runtime/pjrt.rs` uses. Host-side `Literal`
//! plumbing is implemented faithfully (shape + bytes), but anything that
//! needs a real PJRT runtime (`PjRtClient::cpu`, `compile`, `execute`)
//! returns [`XlaError`] at runtime.
//!
//! To run HLO artifacts for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at actual bindings with this interface.

use std::fmt;

#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what} requires the real PJRT runtime — this build links the \
         offline stub (see rust/vendor/xla)"
    ))
}

pub type Result<T> = std::result::Result<T, XlaError>;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn size_bytes(self) -> usize {
        4
    }
}

/// Host element types a [`Literal`] can be decoded into.
pub trait NativeType: Sized {
    const TY: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> f32 {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> i32 {
        i32::from_le_bytes(bytes)
    }
}

#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal: shape + raw little-endian bytes (faithful implementation).
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if data.len() != n * ty.size_bytes() {
            return Err(XlaError(format!(
                "literal shape {dims:?} wants {} bytes, got {}",
                n * ty.size_bytes(),
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), bytes: data.to_vec() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.iter().map(|&d| d as i64).collect(), ty: self.ty })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(XlaError(format!(
                "literal is {:?}, asked to decode as {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let data: Vec<u8> = [1.0f32, -2.5, 3.25]
            .iter()
            .flat_map(|f| f.to_le_bytes())
            .collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &data).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.25]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn runtime_entry_points_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
