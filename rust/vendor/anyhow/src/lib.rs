//! Offline stand-in for the `anyhow` crate (the sandbox has no registry
//! access). Implements exactly the subset this repository uses — `Result`,
//! `Error`, `anyhow!`, `bail!`, and the `Context` extension trait — with the
//! same observable formatting behavior:
//!
//! - `{}` displays the outermost message,
//! - `{:#}` displays the whole context chain joined by `: `,
//! - `{:?}` displays the message plus a `Caused by:` list.
//!
//! Swap the path dependency in Cargo.toml for the real crates.io `anyhow`
//! when a registry is available; no source changes are needed.

use std::fmt;

/// `Result<T, anyhow::Error>` with the usual default type parameter.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chained error. Cheap, string-based: this shim does not keep the
/// source error object alive, only its rendered messages.
pub struct Error {
    /// Outermost context first; the root cause is last. Never empty.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Prepend a layer of context (what `Context::context` attaches).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The rendered context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    fn outer(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("error")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.outer())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.outer())?;
        for cause in self.chain.iter().skip(1) {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

// Like the real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(format!($msg)) };
    ($fmt:literal, $($arg:tt)*) => { $crate::Error::msg(format!($fmt, $($arg)*)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::anyhow!($($t)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("value {x} bad");
        assert_eq!(format!("{e}"), "value 3 bad");
        let e = anyhow!("{} and {}", 1, 2);
        assert_eq!(format!("{e}"), "1 and 2");
        let owned = String::from("plain");
        let e = anyhow!(owned);
        assert_eq!(format!("{e}"), "plain");
    }

    #[test]
    fn bail_returns_err() {
        fn f(flag: bool) -> Result<u32> {
            if flag {
                bail!("flagged {}", 7);
            }
            Ok(1)
        }
        assert!(f(false).is_ok());
        assert_eq!(format!("{}", f(true).unwrap_err()), "flagged 7");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let d = format!("{e:?}");
        assert!(d.contains("outer"));
        assert!(d.contains("Caused by"));
        assert!(d.contains("missing file"));
    }
}
